"""Adaptive DP×CP token dispatcher (DESIGN.md §Dispatch).

The static execution model pins every batch to the full ``model`` mesh
axis: a batch of short documents pays CP-degree collectives it does not
need, and DP ranks sample documents independently with no cross-rank
balancing — one rank drawing a heavy-tail document taxes every rank,
because step time is the max over ranks.

The dispatcher replaces both decisions per global step:

1. **CP group sizing** — the ``data × model`` device grid is re-tiled
   into ``n_devices / cp`` CP subgroups of ``cp`` devices each
   (:func:`repro.launch.mesh.make_group_mesh`), where ``cp`` adapts to
   the step's document-length profile.  Short-doc mixes run at CP 1/2
   (the whole-doc last-shard property makes KV exchange vanish and the
   ``(N-1)`` collective factor shrinks); heavy-tail mixes escalate to the
   full ``model`` axis so one long document spreads over enough ranks.
   Per-device token count is invariant across degrees: ``n_seqs * C /
   n_devices`` regardless of ``cp``.
2. **Cross-group token/workload dispatch** — the step's document pool is
   packed into per-sequence bins (capacity-LPT, :func:`pack_pool`) and
   bins are LPT-assigned to groups by attention workload
   (:func:`lpt_assign`), bounding both token and workload imbalance
   across *all* ``D × M`` devices, not just within one CP group.

Degree selection is simulation-driven: every admissible degree is packed
and assigned (host-side numpy, microseconds at batch scale), and the
smallest degree whose token *and* workload imbalance meet
``target_imbalance`` wins — smaller degrees strictly reduce collective
traffic, so feasibility is the only reason to escalate.  Ties and
infeasible profiles fall back to the most-balanced (then largest) degree.

This module is host-side only (numpy, no JAX); the emitted
:class:`DispatchPlan` feeds the data pipeline
(:func:`repro.data.pipeline.make_dispatch_batch`), which plans each bin
through the ordinary ``get_planner`` / ``encode_plan_batch`` /
``emit_visit_tables`` path at the chosen degree.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .balance import (PackedPool, effective_imbalance, imbalance,
                      lpt_assign, pack_pool)
from .profile import LengthProfile, profile_lengths

__all__ = ["DispatchConfig", "DispatchPlan", "cp_degree_options",
           "dispatch_step", "estimate_comm_tokens"]


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Static dispatcher parameters (one per training run).

    ``data`` / ``model`` are the base mesh axis sizes; ``seqs`` the
    number of packed sequences per *global* step (the batch axis of the
    emitted arrays, sharded over the group axis of the re-tiled mesh).
    """

    data: int = 1
    model: int = 1
    seqs: int = 1
    target_imbalance: float = 1.1
    min_cp: int = 1
    max_cp: int = 0          # 0 -> model axis size
    fixed_cp: int = 0        # >0 pins the degree (adaptivity off)
    #: per-worker slice alignment: a degree is admissible only if
    #: ``(C / cp) % quantum == 0``.  Pass the pipeline's Pallas block
    #: alignment (the visit tables need block-divisible rank slices);
    #: 0/1 = no alignment constraint.  Admissibility only — bin fills
    #: are never trimmed to it.
    quantum: int = 0
    #: bin-fill divisibility floor: bin totals are trimmed to a multiple
    #: of ``lcm(cp, bin_quantum)`` (default: ``cp`` alone — the
    #: planner's Eq. 2 requirement).  Set it to an lcm of degrees under
    #: comparison to make packing degree-invariant (parity harnesses).
    bin_quantum: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model


@dataclasses.dataclass
class DispatchPlan:
    """One global step's dispatch decision (host-side).

    Rows are ordered by group: rows ``[g * seqs_per_group, (g + 1) *
    seqs_per_group)`` belong to CP subgroup ``g`` — exactly the contiguous
    batch slices pjit places on the re-tiled mesh's group axis.
    """

    cp_degree: int
    n_groups: int
    seqs_per_group: int
    rows: list[np.ndarray]          # per-row doc lengths, group-major
    row_docs: list[np.ndarray]      # pool indices backing each row
    group_of_row: np.ndarray        # (n_seqs,) int64
    group_tokens: np.ndarray        # (n_groups,) int64 valid tokens
    group_workload: np.ndarray      # (n_groups,) float64
    token_imbalance: float
    work_imbalance: float
    truncated_tokens: int
    est_comm_tokens: int
    profile: LengthProfile
    candidates: list[dict]          # per-degree evaluation summaries
    #: per-group speed factors the plan balanced against (None = uniform).
    #: When set, ``token_imbalance``/``work_imbalance`` are *effective*
    #: (speed-normalized completion-time) imbalances — the step-time
    #: quantity — and the raw load ratios live in the stats dict.
    group_speeds: np.ndarray | None = None

    def stats(self) -> dict:
        out = {
            "cp_degree": self.cp_degree,
            "n_groups": self.n_groups,
            "token_imbalance": self.token_imbalance,
            "work_imbalance": self.work_imbalance,
            "truncated_tokens": self.truncated_tokens,
            "est_comm_tokens": self.est_comm_tokens,
            "group_tokens": self.group_tokens.tolist(),
        }
        if self.group_speeds is not None:
            out["group_speeds"] = [float(s) for s in self.group_speeds]
            out["work_imbalance_raw"] = imbalance(self.group_workload)
            out["token_imbalance_raw"] = imbalance(self.group_tokens)
        return out


def cp_degree_options(cfg: DispatchConfig, context_len: int,
                      *, strict: bool = True) -> list[int]:
    """Admissible CP degrees, ascending.

    A degree ``g`` is admissible iff the mesh re-tiles cleanly and the
    batch stays SPMD-shardable:

    * ``g`` divides the ``model`` axis (subgroups split the CP axis, never
      a data row);
    * ``seqs`` divides evenly over the ``n_devices / g`` groups (the batch
      axis shards the group axis without remainder);
    * ``context_len`` divides by ``g`` (Eq. 2's equal-token layout) *and*
      the per-worker slice ``C / g`` divides by the configured quantum —
      with the Pallas block size as the quantum this is exactly the
      "block-divisible rank slices" requirement of the visit tables.

    ``strict=False`` returns ``[]`` instead of raising when no degree (or
    a pinned ``fixed_cp``) is admissible — the autotuner probes whole
    config spaces and treats an empty list as "candidate inadmissible"
    (DESIGN.md §Autotune).
    """
    hi = cfg.max_cp or cfg.model
    q = max(cfg.quantum, 1)
    opts = []
    for g in range(1, cfg.model + 1):
        if cfg.model % g or g < cfg.min_cp or g > hi:
            continue
        n_groups = cfg.n_devices // g
        if cfg.seqs % n_groups:
            continue
        if context_len % g or (context_len // g) % q:
            continue
        if context_len % _bin_quantum(cfg, g):
            continue
        opts.append(g)
    if cfg.fixed_cp:
        if cfg.fixed_cp not in opts:
            if not strict:
                return []
            raise ValueError(
                f"fixed_cp={cfg.fixed_cp} inadmissible for mesh "
                f"{cfg.data}x{cfg.model}, seqs={cfg.seqs}, "
                f"C={context_len} (admissible: {opts})")
        return [cfg.fixed_cp]
    if not opts and strict:
        raise ValueError(
            f"no admissible CP degree for mesh {cfg.data}x{cfg.model}, "
            f"seqs={cfg.seqs}, C={context_len}")
    return opts


def _bin_quantum(cfg: DispatchConfig, g: int) -> int:
    return int(np.lcm(g, max(cfg.bin_quantum, 1)))


def estimate_comm_tokens(doc_lens, cp: int, context_len: int) -> int:
    """Cheap Eq. 5 proxy for one sequence at degree ``cp``.

    Tokens of each document beyond one worker's equal-token share must sit
    on other workers as non-last shards, so they are the floor of what the
    sharding-aware exchange moves.  Used only for candidate tie-breaking
    and logging — benchmarks recompute exact volumes from real plans.
    """
    if cp <= 1:
        return 0
    t_loc = context_len // cp
    lens = np.asarray(doc_lens, dtype=np.int64)
    return int(np.maximum(lens - t_loc, 0).sum())


def _group_speeds(device_speeds, n_groups: int, g: int) -> np.ndarray | None:
    """Per-group speed at degree ``g``: the slowest member bounds its
    group's CP step (groups are contiguous device slices)."""
    if device_speeds is None:
        return None
    ds = np.asarray(device_speeds, dtype=np.float64)
    assert ds.shape == (n_groups * g,) and (ds > 0).all(), ds
    gs = ds.reshape(n_groups, g).min(axis=1)
    gs = gs / gs.max()
    return None if np.allclose(gs, 1.0) else gs


def _evaluate(cfg: DispatchConfig, pool: np.ndarray, context_len: int,
              g: int, device_speeds=None) -> dict:
    n_groups = cfg.n_devices // g
    per_group = cfg.seqs // n_groups
    speeds = _group_speeds(device_speeds, n_groups, g)
    targets = None
    if speeds is not None:
        # capacity-proportional bin shaping: per_group bins per group
        # with fill targets ∝ group speed (quantum-floored) — the light
        # bins the speed-aware LPT routes onto slow groups.
        q = _bin_quantum(cfg, g)
        f = (np.floor(context_len * speeds / q) * q).astype(np.int64)
        targets = np.repeat(np.maximum(f, q), per_group)
    packed = pack_pool(pool, cfg.seqs, context_len,
                       quantum=_bin_quantum(cfg, g), targets=targets)
    tokens = packed.bin_tokens
    work = packed.bin_workloads
    assign = lpt_assign(work, n_groups, per_group=per_group, speeds=speeds)
    g_tok = np.bincount(assign, weights=tokens,
                        minlength=n_groups).astype(np.int64)
    g_work = np.bincount(assign, weights=work, minlength=n_groups)
    comm = sum(estimate_comm_tokens(b, g, context_len) for b in packed.bins)
    return {
        "cp_degree": g,
        "n_groups": n_groups,
        "seqs_per_group": per_group,
        "packed": packed,
        "assign": assign,
        "group_tokens": g_tok,
        "group_workload": g_work,
        "group_speeds": speeds,
        "token_imbalance": effective_imbalance(g_tok, speeds),
        "work_imbalance": effective_imbalance(g_work, speeds),
        "est_comm_tokens": int(comm),
    }


def dispatch_step(doc_pool, cfg: DispatchConfig, context_len: int,
                  device_speeds=None) -> DispatchPlan:
    """Size the CP groups and dispatch one step's document pool.

    Evaluates every admissible degree (ascending) by actually packing and
    LPT-assigning the pool, then picks the smallest degree whose token and
    workload imbalance both meet ``cfg.target_imbalance`` — smaller
    degrees never move more KV, so feasibility alone decides escalation.
    If no degree meets the target, the most-balanced (workload, then
    larger-degree) candidate wins.

    ``device_speeds``: optional per-device speed factors (flat device
    order, length ``cfg.n_devices``) from the straggler monitor
    (DESIGN.md §Recovery).  Candidates are then packed with
    speed-proportional bin targets, assigned by capacity-proportional
    LPT, and judged on *effective* (speed-normalized completion-time)
    imbalance — slow survivors get lighter bins instead of bounding
    every step.
    """
    pool = np.asarray(doc_pool, dtype=np.int64)
    opts = cp_degree_options(cfg, context_len)
    cands = [_evaluate(cfg, pool, context_len, g, device_speeds)
             for g in opts]

    chosen = None
    for c in cands:
        if c["token_imbalance"] <= cfg.target_imbalance and \
                c["work_imbalance"] <= cfg.target_imbalance:
            chosen = c
            break
    if chosen is None:
        chosen = min(cands,
                     key=lambda c: (c["work_imbalance"], -c["cp_degree"]))

    packed: PackedPool = chosen["packed"]
    assign = chosen["assign"]
    order = np.lexsort((np.arange(cfg.seqs), assign))   # group-major rows
    prof = profile_lengths(
        pool, tail_len=context_len // cfg.model if cfg.model > 1 else 0)

    def summary(c):
        return {k: v for k, v in c.items()
                if k not in ("packed", "assign", "group_tokens",
                             "group_workload", "group_speeds")} | {
            "token_imbalance": float(c["token_imbalance"]),
            "work_imbalance": float(c["work_imbalance"])}

    return DispatchPlan(
        cp_degree=chosen["cp_degree"],
        n_groups=chosen["n_groups"],
        seqs_per_group=chosen["seqs_per_group"],
        rows=[packed.bins[i] for i in order],
        row_docs=[packed.bin_docs[i] for i in order],
        group_of_row=assign[order],
        group_tokens=chosen["group_tokens"],
        group_workload=chosen["group_workload"],
        token_imbalance=float(chosen["token_imbalance"]),
        work_imbalance=float(chosen["work_imbalance"]),
        truncated_tokens=packed.truncated_tokens,
        est_comm_tokens=chosen["est_comm_tokens"],
        profile=prof,
        candidates=[summary(c) for c in cands],
        group_speeds=chosen["group_speeds"],
    )
