"""Cross-group token/workload balancing primitives (DESIGN.md §Dispatch).

Two host-side assignment problems, both solved with LPT-family greedy
algorithms over the planner's vectorized workload accounting
(:func:`repro.planner.plan.shard_workload_array`):

* **pool → sequence bins** (:func:`pack_pool`): the global step's document
  pool is packed into ``n_bins`` sequence windows of ``capacity`` tokens.
  Worst-fit-decreasing (capacity-constrained LPT on *token counts*) keeps
  bin fills near-equal, so the batch stays only mildly ragged; a document
  that fits no bin is truncated into the emptiest one (the same remedy the
  per-rank packer applies at the window boundary).  Bin totals are rounded
  down to a ``quantum`` so every bin satisfies the planner's equal-token
  divisibility (Eq. 2 needs ``tokens % cp == 0``).
* **bins → DP×CP groups** (:func:`lpt_assign`): sequences are assigned to
  groups in decreasing *attention-workload* order, each to the least-loaded
  group with slots remaining (cardinality-constrained LPT) — every group
  receives exactly ``n_bins / n_groups`` sequences, so the batch axis
  shards evenly over the group (``"data"``) mesh axis.

Both primitives are *speed-aware* (DESIGN.md §Recovery): a per-group
``speeds`` vector turns :func:`lpt_assign` into capacity-proportional LPT
(the greedy minimizes the *completion time* ``(load + w) / speed``, so a
group at speed 0.5 receives roughly half the workload), and per-bin fill
``targets`` let :func:`pack_pool` shape bins to the speed distribution.
The straggler monitor's per-host EMA feeds these live — persistently slow
survivors get lighter bins instead of bounding every step.

Everything is pure numpy + Python; determinism follows from stable sorts
keyed on (weight, original index).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.planner.plan import shard_workload_array

__all__ = ["PackedPool", "sequence_workload", "pack_pool", "lpt_assign",
           "imbalance", "effective_imbalance"]


def sequence_workload(doc_lens) -> float:
    """Causal attention workload of one packed sequence: Σ_i d_i(d_i+1)/2.

    The whole-document case of the paper's shard workload W_i (prefix 0) —
    the quantity FlashCP balances *within* a CP group; the dispatcher
    balances its per-sequence sum *across* groups.
    """
    lens = np.asarray(doc_lens, dtype=np.int64)
    return float(shard_workload_array(np.zeros_like(lens), lens).sum())


def imbalance(loads) -> float:
    """max / mean of a load vector (1.0 = perfectly balanced)."""
    loads = np.asarray(loads, dtype=np.float64)
    if loads.size == 0:
        return 1.0
    avg = float(loads.mean())
    if avg <= 0.0:
        return 1.0
    return float(loads.max()) / avg


def effective_imbalance(loads, speeds=None) -> float:
    """Completion-time imbalance: max/mean of ``load / speed``.

    With ``speeds=None`` this is plain :func:`imbalance`.  Step time is
    the max over groups of the time each group needs, so a group at speed
    0.5 holding the mean load takes 2x the mean time — the quantity the
    speed-weighted dispatcher balances."""
    loads = np.asarray(loads, dtype=np.float64)
    if speeds is None:
        return imbalance(loads)
    speeds = np.asarray(speeds, dtype=np.float64)
    assert speeds.shape == loads.shape and (speeds > 0).all(), speeds
    return imbalance(loads / speeds)


@dataclasses.dataclass
class PackedPool:
    """Result of :func:`pack_pool`.

    ``bins[b]`` holds the (possibly truncated) document lengths of sequence
    ``b`` and ``bin_docs[b]`` the pool indices they came from, aligned
    element-for-element.  Every pool document appears in exactly one bin or
    in ``dropped_docs`` (truncated to nothing) — never both, never twice.
    """

    bins: list[np.ndarray]          # per-bin doc lengths (int64)
    bin_docs: list[np.ndarray]      # per-bin pool indices (int64)
    dropped_docs: np.ndarray        # pool indices truncated to zero length
    truncated_tokens: int           # pool tokens not placed in any bin

    @property
    def bin_tokens(self) -> np.ndarray:
        return np.asarray([int(b.sum()) for b in self.bins], np.int64)

    @property
    def bin_workloads(self) -> np.ndarray:
        return np.asarray([sequence_workload(b) for b in self.bins])


def pack_pool(doc_lens, n_bins: int, capacity: int, *,
              quantum: int = 1, targets=None) -> PackedPool:
    """Pack a document pool into ``n_bins`` sequence windows.

    Worst-fit-decreasing: documents are placed largest-first into the bin
    with the lowest current fill among bins with room — the
    capacity-constrained LPT that keeps per-bin token counts near-equal.
    A document that fits no bin is truncated into the bin with the most
    remaining room (``truncated_tokens`` accounts for the cut); afterwards
    each bin is trimmed so its total is a multiple of ``quantum``
    (trimming comes off the bin's largest documents, mirroring the
    per-rank packer's end-of-window truncation).

    ``targets``: optional per-bin fill targets (clipped to ``capacity``) —
    the speed-weighted dispatcher passes targets proportional to each
    prospective group's speed so slow groups receive lighter sequences
    (DESIGN.md §Recovery).  Fill-relative decisions ("lowest fill",
    "most room") are measured against each bin's own target, so a
    half-target bin at half fill is as "full" as a full-target bin at
    full fill.
    """
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    assert n_bins > 0 and capacity > 0 and quantum >= 1
    assert capacity % quantum == 0, (capacity, quantum)
    if targets is None:
        target = np.full(n_bins, capacity, np.int64)
    else:
        target = np.minimum(np.asarray(targets, np.int64), capacity)
        assert target.shape == (n_bins,), target.shape
        # a bin must hold at least one quantum or it becomes an empty row
        target = np.maximum(target, quantum)

    order = np.lexsort((np.arange(len(doc_lens)), -doc_lens))
    bins: list[list[int]] = [[] for _ in range(n_bins)]
    docs: list[list[int]] = [[] for _ in range(n_bins)]
    fill = np.zeros(n_bins, np.int64)
    dropped: list[int] = []
    truncated = 0

    for i in order:
        d = int(min(doc_lens[i], int(target.max())))
        truncated += int(doc_lens[i]) - d
        room = target - fill
        fits = np.nonzero(room >= d)[0]
        if len(fits):
            # least-filled bin (relative to target) with room;
            # ties -> lowest index (stable)
            rel = fill[fits] / target[fits]
            b = int(fits[np.argmin(rel)])
            take = d
        else:
            b = int(np.argmax(room))
            take = int(room[b])
            truncated += d - take
            if take == 0:
                dropped.append(int(i))
                continue
        bins[b].append(take)
        docs[b].append(int(i))
        fill[b] += take

    if quantum > 1:
        for b in range(n_bins):
            trim = int(fill[b] % quantum)
            while trim > 0 and bins[b]:
                j = int(np.argmax(bins[b]))
                cut = min(trim, bins[b][j])
                bins[b][j] -= cut
                trim -= cut
                truncated += cut
                fill[b] -= cut
                if bins[b][j] == 0:
                    dropped.append(docs[b].pop(j))
                    bins[b].pop(j)

    return PackedPool(
        bins=[np.asarray(b, np.int64) for b in bins],
        bin_docs=[np.asarray(d, np.int64) for d in docs],
        dropped_docs=np.asarray(sorted(dropped), np.int64),
        truncated_tokens=truncated,
    )


def lpt_assign(weights, n_groups: int, *, per_group: int | None = None,
               speeds=None) -> np.ndarray:
    """LPT assignment of weighted items to groups.

    Returns ``group_of_item`` (int64).  With ``per_group`` set, every group
    receives exactly that many items (cardinality-constrained LPT: each
    item goes to the least-loaded group with slots left); the classic LPT
    bound ``max_load <= mean_load + max(weight)`` still holds because the
    slot constraint only binds once loads are within one item of each
    other.

    ``speeds``: optional per-group positive speed factors (1.0 = full
    speed).  The greedy then minimizes projected *completion time*
    ``(load + w) / speed`` — capacity-proportional LPT on uniform
    machines (Q||Cmax): a group at speed 0.5 ends up with roughly half
    the load, so a persistent straggler stops bounding the step
    (DESIGN.md §Recovery).  ``speeds=None`` is exactly the classic path.
    """
    weights = np.asarray(weights, dtype=np.float64)
    n = len(weights)
    assert n_groups > 0
    if per_group is not None:
        assert per_group * n_groups == n, (n, n_groups, per_group)
    if speeds is not None:
        speeds = np.asarray(speeds, dtype=np.float64)
        assert speeds.shape == (n_groups,) and (speeds > 0).all(), speeds
    order = np.lexsort((np.arange(n), -weights))
    load = np.zeros(n_groups, np.float64)
    count = np.zeros(n_groups, np.int64)
    out = np.empty(n, np.int64)
    for i in order:
        open_g = np.nonzero(count < per_group)[0] if per_group is not None \
            else np.arange(n_groups)
        if speeds is None:
            g = int(open_g[np.argmin(load[open_g])])
        else:
            eta = (load[open_g] + weights[i]) / speeds[open_g]
            g = int(open_g[np.argmin(eta)])
        out[i] = g
        load[g] += weights[i]
        count[g] += 1
    return out
