"""Document-length profiling for CP group sizing (DESIGN.md §Dispatch).

A :class:`LengthProfile` summarizes one global step's document pool: the
quantiles and tail mass that decide whether the step is a "short-doc" mix
(tiny CP groups suffice — nearly every document is its own last shard, so
KV exchange is near-zero at any degree and smaller groups cut the
``(N-1)`` collective factor) or a "heavy-tail" mix (long documents must
spread over many ranks before per-device workload balances).

The profile is cheap (one sort over the pool) and is attached to the
emitted :class:`repro.dispatch.dispatcher.DispatchPlan` for logging and
benchmarks; the degree *decision* itself is simulation-driven — see
:func:`repro.dispatch.dispatcher.dispatch_step`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LengthProfile", "profile_lengths"]


@dataclasses.dataclass(frozen=True)
class LengthProfile:
    """Summary statistics of a document-length pool (tokens)."""

    n_docs: int
    total_tokens: int
    max_len: int
    p50: int
    p90: int
    p99: int
    #: fraction of pool *tokens* living in documents longer than the
    #: reference length passed to :func:`profile_lengths` (default: one
    #: static CP shard, C / N_model) — the mass that forces KV exchange.
    tail_token_frac: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def profile_lengths(doc_lens, *, tail_len: int = 0) -> LengthProfile:
    """Profile a pool of document lengths.

    ``tail_len``: documents strictly longer than this are counted into
    ``tail_token_frac`` (0 disables the tail split).
    """
    lens = np.asarray(doc_lens, dtype=np.int64)
    if lens.size == 0:
        return LengthProfile(0, 0, 0, 0, 0, 0, 0.0)
    total = int(lens.sum())
    tail = int(lens[lens > tail_len].sum()) if tail_len > 0 else 0
    p50, p90, p99 = (int(np.percentile(lens, q)) for q in (50, 90, 99))
    return LengthProfile(
        n_docs=int(lens.size),
        total_tokens=total,
        max_len=int(lens.max()),
        p50=p50, p90=p90, p99=p99,
        tail_token_frac=tail / total if total else 0.0,
    )
