"""Adaptive DP×CP token dispatcher (DESIGN.md §Dispatch).

Sits between the data pipeline and the planner registry: per global step,
:func:`dispatch_step` sizes the CP subgroups from the document-length
profile and LPT-dispatches the step's documents across the resulting
DP×CP groups with cross-rank token/workload balancing.  The emitted
:class:`DispatchPlan` drives :func:`repro.data.pipeline.make_dispatch_batch`
(per-group planning/encoding at the chosen degree) and
:func:`repro.launch.mesh.make_group_mesh` (device-grid re-tiling).

Host-side numpy only — importable by benchmarks and tests without JAX.
"""

from .balance import (PackedPool, effective_imbalance, imbalance,
                      lpt_assign, pack_pool, sequence_workload)
from .dispatcher import (DispatchConfig, DispatchPlan, cp_degree_options,
                         dispatch_step, estimate_comm_tokens)
from .profile import LengthProfile, profile_lengths

__all__ = [
    "PackedPool", "effective_imbalance", "imbalance", "lpt_assign",
    "pack_pool", "sequence_workload",
    "DispatchConfig", "DispatchPlan", "cp_degree_options", "dispatch_step",
    "estimate_comm_tokens",
    "LengthProfile", "profile_lengths",
]
