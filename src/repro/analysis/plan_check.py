"""Layer 1 — static plan & table verification (DESIGN.md §Static-analysis).

Pure host-numpy structural checks over planner outputs.  Nothing here
raises on a violation — every check returns :class:`Finding` records so
the CLI can report all problems in one pass and tests can assert on rule
ids.  The checks intentionally *re-derive* each invariant from first
principles (dense oracles, per-token expansion) rather than reusing the
planner's own accounting, so a bug in the fast vectorized path cannot
hide itself.

Rule ids: PLAN00x (shard plans), ENC00x (encodings), TAB00x (visit
tables), WQ00x (work queues), SRV00x (serve block tables).
"""

from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding
from repro.kernels.doc_attention import FLAG_FIRST, FLAG_LAST, FLAG_VALID
from repro.planner.encode import PlanEncoding
from repro.planner.plan import ShardingPlan

__all__ = [
    "check_plan",
    "check_encoding",
    "check_block_tables",
    "check_work_queue",
    "check_serve_state",
]


# --------------------------------------------------------------------- #
# PLAN00x — shard plans
# --------------------------------------------------------------------- #
def check_plan(plan: ShardingPlan, *, max_imbalance: float | None = None,
               require_equal_tokens: bool = True,
               token_tolerance: int = 0,
               context: str = "plan") -> list[Finding]:
    """Structural checks on one :class:`ShardingPlan`.

    ``max_imbalance``: the planner's declared workload bound (None skips
    PLAN004 — baselines like llama3/per_doc are imbalanced by design).
    ``require_equal_tokens``/``token_tolerance``: gate PLAN003 on the
    planner's :class:`PlannerInfo` contract.
    """
    out: list[Finding] = []
    a = plan.arrays
    n_docs = len(plan.doc_lens)
    N = plan.num_workers

    # PLAN002 first: range errors would poison the coverage scan.
    bad_doc = (a.doc_id < 0) | (a.doc_id >= n_docs)
    bad_worker = (a.worker < 0) | (a.worker >= N)
    bad_len = a.length <= 0
    bad_start = a.start < 0
    for mask, what in ((bad_doc, "doc_id out of range"),
                       (bad_worker, "worker out of range"),
                       (bad_len, "non-positive shard length"),
                       (bad_start, "negative shard start")):
        if mask.any():
            i = int(np.flatnonzero(mask)[0])
            out.append(Finding(
                "PLAN002", "error", context,
                f"{what} in {int(mask.sum())} shard(s); first at shard "
                f"{i}: doc={int(a.doc_id[i])} start={int(a.start[i])} "
                f"len={int(a.length[i])} worker={int(a.worker[i])}",
                hint="planner emitted a malformed ShardArrays entry"))
    if any(f.rule == "PLAN002" for f in out):
        return out

    # PLAN001 — exact tiling: per document, shards sorted by start must
    # run 0 .. doc_len with no gap, overlap, or missing document.
    order = np.lexsort((a.start, a.doc_id))
    d, s, e = a.doc_id[order], a.start[order], a.end[order]
    covered = np.bincount(a.doc_id, weights=a.length,
                          minlength=n_docs).astype(np.int64)
    first = np.ones(len(d), dtype=bool)
    first[1:] = d[1:] != d[:-1]
    bad_first = first & (s != 0)
    step = np.zeros(len(d), bool)
    if len(d) > 1:
        step[1:] = (~first[1:]) & (s[1:] != e[:-1])
    overlap = np.zeros(len(d), bool)
    if len(d) > 1:
        overlap[1:] = (~first[1:]) & (s[1:] < e[:-1])
    for i in np.flatnonzero(bad_first)[:3]:
        out.append(Finding(
            "PLAN001", "error", context,
            f"doc {int(d[i])}: first shard starts at {int(s[i])}, "
            f"token range [0, {int(s[i])}) uncovered",
            hint="every document must be tiled from token 0"))
    for i in np.flatnonzero(step)[:3]:
        kind = "double-covered" if overlap[i] else "uncovered"
        lo, hi = sorted((int(e[i - 1]), int(s[i])))
        out.append(Finding(
            "PLAN001", "error", context,
            f"doc {int(d[i])}: tokens [{lo}, {hi}) {kind} "
            f"(shard boundary {int(e[i - 1])} vs next start {int(s[i])})",
            hint="shards of one document must tile it exactly once"))
    # tail / total coverage (catches missing docs and over-long shards)
    mismatch = np.flatnonzero(covered != plan.doc_lens)
    if not (bad_first.any() or step.any()):
        for i in mismatch[:3]:
            out.append(Finding(
                "PLAN001", "error", context,
                f"doc {int(i)}: shards cover {int(covered[i])} of "
                f"{int(plan.doc_lens[i])} tokens",
                hint="document not fully covered by its shards"))
    # last-shard end must equal doc_len even when totals happen to match
    last = np.ones(len(d), dtype=bool)
    last[:-1] = d[:-1] != d[1:]
    bad_end = last & (e != plan.doc_lens[d])
    if not (bad_first.any() or step.any() or len(mismatch)):
        for i in np.flatnonzero(bad_end)[:3]:
            out.append(Finding(
                "PLAN001", "error", context,
                f"doc {int(d[i])}: last shard ends at {int(e[i])}, "
                f"doc_len is {int(plan.doc_lens[d[i]])}",
                hint="shards of one document must tile it exactly once"))

    # PLAN003 — Eq.2 equal tokens
    if require_equal_tokens:
        tok = plan.tokens_per_worker()
        target = plan.context_len / N
        off = np.abs(tok - target)
        if (off > token_tolerance).any():
            j = int(np.argmax(off))
            out.append(Finding(
                "PLAN003", "error", context,
                f"equal-token constraint violated: worker {j} holds "
                f"{int(tok[j])} tokens, target C/N = {target:g} "
                f"(tolerance {token_tolerance})",
                hint="Eq.2: every CP rank must hold C/N tokens"))

    # PLAN004 — declared workload bound
    if max_imbalance is not None:
        imb = plan.imbalance_ratio()
        if imb > max_imbalance + 1e-9:
            out.append(Finding(
                "PLAN004", "error", context,
                f"workload imbalance {imb:.4f} exceeds declared bound "
                f"{max_imbalance:.4f}",
                hint="planner exceeded its own balance guarantee"))
    return out


# --------------------------------------------------------------------- #
# ENC00x — plan encodings
# --------------------------------------------------------------------- #
def _token_shard_is_last(plan: ShardingPlan) -> np.ndarray:
    """(C,) bool per *packed position*: does this token live in a last
    shard?  Expanded directly from the shard arrays."""
    a = plan.arrays
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]
    C = plan.context_len
    out = np.zeros(C, dtype=bool)
    is_last = a.is_last(plan.doc_lens)
    for ds, st, ln, il in zip(doc_starts[a.doc_id], a.start, a.length,
                              is_last):
        out[int(ds + st): int(ds + st + ln)] = bool(il)
    return out


def check_encoding(plan: ShardingPlan, enc: PlanEncoding, *,
                   context: str = "encoding") -> list[Finding]:
    """ENC001-ENC005 over one (plan, encoding) pair."""
    out: list[Finding] = []
    N = plan.num_workers
    C = plan.context_len
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]

    perm, doc, pos = enc.perm, enc.doc, enc.pos
    valid = perm >= 0

    # ENC001 — perm restricted to valid slots is a permutation of 0..C-1
    vals = np.sort(perm[valid])
    if len(vals) != C or not np.array_equal(vals, np.arange(C)):
        dup = vals[:-1][vals[1:] == vals[:-1]] if len(vals) > 1 else []
        missing = np.setdiff1d(np.arange(C), vals)
        out.append(Finding(
            "ENC001", "error", context,
            f"perm is not a permutation of 0..{C - 1}: "
            f"{len(vals)} valid entries, "
            f"{len(np.unique(vals))} distinct"
            + (f", first duplicate {int(dup[0])}" if len(dup) else "")
            + (f", first missing {int(missing[0])}" if len(missing) else ""),
            hint="every packed token must appear exactly once in plan order"))
        return out   # downstream checks need a valid perm

    if ((doc >= 0) != valid).any():
        out.append(Finding(
            "ENC002", "error", context,
            "doc >= 0 does not coincide with perm >= 0 padding",
            hint="pad slots must be -1 in both perm and doc"))

    # ENC002 — doc/pos agree with perm: packed = doc_start[doc] + pos
    recon = np.where(valid, doc_starts[np.maximum(doc, 0)] + pos, -1)
    bad = valid & (recon != perm)
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        out.append(Finding(
            "ENC002", "error", context,
            f"doc/pos inconsistent with perm at plan slot {i}: "
            f"doc={int(doc[i])} pos={int(pos[i])} -> packed "
            f"{int(recon[i])}, perm says {int(perm[i])}",
            hint="encoded token metadata must match the shard layout"))

    # ---- send buffers ------------------------------------------------- #
    t_loc, buf_len = enc.t_loc, enc.buf_len
    is_last_tok = _token_shard_is_last(plan)   # per packed position
    sent_packed: list[np.ndarray] = []
    for j in range(N):
        sl = enc.send_idx[j]
        taken = sl >= 0
        li = sl[taken].astype(np.int64)
        if (li >= t_loc).any():
            out.append(Finding(
                "ENC004", "error", context,
                f"worker {j}: send_idx exceeds t_loc={t_loc}",
                hint="send indices are local to the worker's token slice"))
            continue
        plan_slots = j * t_loc + li
        if (perm[plan_slots] < 0).any():
            out.append(Finding(
                "ENC004", "error", context,
                f"worker {j}: send buffer references padding slots",
                hint="only real tokens may be sent"))
            continue
        pk = perm[plan_slots]
        sent_packed.append(pk)
        # gathered metadata must mirror the sent tokens
        gd = enc.gath_doc[j * buf_len: j * buf_len + len(sl)][taken]
        gp = enc.gath_pos[j * buf_len: j * buf_len + len(sl)][taken]
        if (gd != doc[plan_slots]).any() or (gp != pos[plan_slots]).any():
            out.append(Finding(
                "ENC002", "error", context,
                f"worker {j}: gath_doc/gath_pos disagree with the sent "
                f"tokens' doc/pos",
                hint="gathered metadata must describe the send buffer"))
        # ENC004 — Eq.5: sent tokens must all be non-last-shard tokens
        redundant = is_last_tok[pk]
        if redundant.any():
            i = int(pk[np.flatnonzero(redundant)[0]])
            out.append(Finding(
                "ENC004", "error", context,
                f"worker {j} sends {int(redundant.sum())} last-shard "
                f"token(s) (first: packed position {i}) — redundant KV "
                f"communication the paper's Eq.5 eliminates",
                hint="only non-last document shards contribute to the "
                     "exchange buffer"))

    # ENC005 — completeness: every non-last-shard token is sent once
    want = np.flatnonzero(~is_last_tok)
    got = np.concatenate(sent_packed) if sent_packed else \
        np.zeros(0, np.int64)
    got_sorted = np.sort(got)
    if len(got_sorted) != len(np.unique(got_sorted)):
        out.append(Finding(
            "ENC004", "error", context,
            "a token appears more than once across send buffers",
            hint="each non-last shard token is sent exactly once"))
    missing = np.setdiff1d(want, got_sorted)
    if len(missing):
        out.append(Finding(
            "ENC005", "error", context,
            f"{len(missing)} non-last shard token(s) missing from the "
            f"send buffers (first: packed position {int(missing[0])})",
            hint="Eq.4/5 exchange must carry every non-last shard token"))

    # ENC003 — causal closure: for each worker, every prefix position of
    # every local query token is available locally or in the gathered
    # buffers.  (doc, pos) availability via a composite-key set.
    key = np.int64(1) << 32
    gath_valid = enc.gath_doc >= 0
    gkeys = (enc.gath_doc[gath_valid].astype(np.int64) * key
             + enc.gath_pos[gath_valid])
    for j in range(N):
        sl = slice(j * t_loc, (j + 1) * t_loc)
        ld, lp = doc[sl], pos[sl]
        lv = ld >= 0
        avail = np.union1d(ld[lv].astype(np.int64) * key + lp[lv], gkeys)
        # needed: for each local (d, p), all (d, p') p' < p.  Checking
        # every prefix position is O(C^2) worst case; instead verify the
        # equivalent interval condition per doc: available positions of
        # doc d on this worker must cover [0, max_local_pos(d)].
        for dd in np.unique(ld[lv]):
            need_hi = int(lp[lv][ld[lv] == dd].max())
            have = np.sort(avail[(avail >= dd * key)
                                 & (avail < (dd + 1) * key)] - dd * key)
            # positions present for doc dd (local + gathered)
            cover = np.searchsorted(have, np.arange(need_hi + 1))
            present = (cover < len(have)) & \
                (have[np.minimum(cover, len(have) - 1)]
                 == np.arange(need_hi + 1))
            if not present.all():
                p_miss = int(np.flatnonzero(~present)[0])
                out.append(Finding(
                    "ENC003", "error", context,
                    f"worker {j}, doc {int(dd)}: query at position "
                    f"{need_hi} cannot see prefix position {p_miss} "
                    f"(neither local nor gathered)",
                    hint="causal closure: the exchange must deliver every "
                         "remote prefix KV"))
                break
    return out


# --------------------------------------------------------------------- #
# TAB00x — visit tables vs. a dense token-level oracle
# --------------------------------------------------------------------- #
def check_block_tables(q_doc, q_pos, kv_doc, kv_pos, kv_idx, kv_nvis, *,
                       block_q: int, block_k: int,
                       context: str = "tables") -> list[Finding]:
    """Soundness of one (possibly batched) rectangular visit table.

    ``q_doc``/``q_pos`` (B, Tq) and ``kv_doc``/``kv_pos`` (B, Tk) are the
    token metadata the table was built from; ``kv_idx`` (B, R, V) /
    ``kv_nvis`` (B, R) the table under test.  The oracle is the exact
    token-level visibility ``same doc AND kv_pos <= q_pos AND both
    valid``: every KV block containing at least one visible pair for a
    query block must appear in that block-row's visit list (TAB001).
    Over-visiting is sound (the kernel masks per token) and is not
    flagged.  TAB002 checks index ranges and padding discipline.
    """
    q_doc = np.asarray(q_doc)
    q_pos = np.asarray(q_pos)
    kv_doc = np.asarray(kv_doc)
    kv_pos = np.asarray(kv_pos)
    kv_idx = np.asarray(kv_idx)
    kv_nvis = np.asarray(kv_nvis)
    out: list[Finding] = []
    B, R, V = kv_idx.shape
    nk = kv_doc.shape[-1] // block_k

    # TAB002 — ranges
    if (kv_nvis < 0).any() or (kv_nvis > nk).any():
        out.append(Finding(
            "TAB002", "error", context,
            f"kv_nvis outside [0, {nk}]",
            hint="visit counts must not exceed the KV block count"))
    lane = np.arange(V)[None, None, :]
    used = lane < np.minimum(kv_nvis, V)[..., None]
    if ((kv_idx < 0) & used).any() or ((kv_idx >= nk) & used).any():
        out.append(Finding(
            "TAB002", "error", context,
            f"kv_idx entry outside [0, {nk}) within the visited prefix",
            hint="visit entries must be valid KV block ids"))
    if out:
        return out

    for b in range(B):
        vis = ((q_doc[b][:, None] == kv_doc[b][None, :])
               & (q_doc[b][:, None] >= 0) & (kv_doc[b][None, :] >= 0)
               & (kv_pos[b][None, :] <= q_pos[b][:, None]))
        # block-level any-visible oracle
        blk = vis.reshape(R, block_q, nk, block_k).any((1, 3))
        for r in range(R):
            need = np.flatnonzero(blk[r])
            have = kv_idx[b, r, :kv_nvis[b, r]]
            missing = np.setdiff1d(need, have)
            if len(missing):
                out.append(Finding(
                    "TAB001", "error", context,
                    f"sample {b} q-block {r}: visible KV block(s) "
                    f"{missing[:4].tolist()} not in the visit list — the "
                    f"kernel would silently skip attention mass",
                    hint="table build must be conservative: visit any "
                         "block with one visible pair"))
                if len(out) > 8:
                    return out
    return out


# --------------------------------------------------------------------- #
# WQ00x — flattened work queues
# --------------------------------------------------------------------- #
def check_work_queue(idx, nvis, row, col, flags, *,
                     context: str = "queue") -> list[Finding]:
    """WQ001-WQ003 over one (B, S) work-queue triple against the
    rectangular tables it was flattened from."""
    idx = np.asarray(idx)
    nvis = np.asarray(nvis).astype(np.int64)
    row = np.asarray(row)
    col = np.asarray(col)
    flags = np.asarray(flags)
    out: list[Finding] = []
    B, R, V = idx.shape
    S = row.shape[1]

    for b in range(B):
        nv = nvis[b]
        counts = np.maximum(nv, 1)
        total = int(counts.sum())
        if total > S:
            out.append(Finding(
                "WQ001", "error", f"{context} sample {b}",
                f"queue too short: needs {total} steps, has {S}",
                hint="pad_to_steps below the real step count"))
            continue
        r = row[b, :total]
        f = flags[b, :total]
        c = col[b, :total]

        # rows must form contiguous runs covering every row once
        run_start = np.ones(total, dtype=bool)
        run_start[1:] = r[1:] != r[:-1]
        starts = np.flatnonzero(run_start)
        run_rows = r[starts]
        if len(np.unique(run_rows)) != R or len(run_rows) != R:
            out.append(Finding(
                "WQ001", "error", f"{context} sample {b}",
                f"rows do not form one contiguous run each: "
                f"{len(run_rows)} runs over {R} rows",
                hint="each block-row's steps must be contiguous"))
            continue
        run_len = np.diff(np.append(starts, total))
        bad_len = run_len != counts[run_rows]
        if bad_len.any():
            rr = int(run_rows[np.flatnonzero(bad_len)[0]])
            out.append(Finding(
                "WQ001", "error", f"{context} sample {b}",
                f"row {rr}: run length {int(run_len[run_rows == rr][0])} "
                f"!= expected {int(counts[rr])}",
                hint="one step per visit, one sentinel for empty rows"))

        # flags: FIRST exactly at run starts, LAST exactly at run ends
        ends = np.append(starts[1:], total) - 1
        first_mask = np.zeros(total, dtype=bool)
        first_mask[starts] = True
        last_mask = np.zeros(total, dtype=bool)
        last_mask[ends] = True
        if (((f & FLAG_FIRST) != 0) != first_mask).any():
            i = int(np.flatnonzero(((f & FLAG_FIRST) != 0)
                                   != first_mask)[0])
            out.append(Finding(
                "WQ001", "error", f"{context} sample {b}",
                f"FLAG_FIRST mismatch at step {i} (row {int(r[i])}): "
                f"accumulators would {'not be reset' if first_mask[i] else 'be clobbered mid-row'}",
                hint="FIRST must mark exactly each row's first step"))
        if (((f & FLAG_LAST) != 0) != last_mask).any():
            i = int(np.flatnonzero(((f & FLAG_LAST) != 0)
                                   != last_mask)[0])
            out.append(Finding(
                "WQ001", "error", f"{context} sample {b}",
                f"FLAG_LAST mismatch at step {i} (row {int(r[i])}): "
                f"output block would "
                f"{'never be written' if last_mask[i] else 'be finalized early'}",
                hint="LAST must mark exactly each row's final step"))
        # VALID count per row == nvis; sentinels carry no VALID
        vcount = np.bincount(r[(f & FLAG_VALID) != 0], minlength=R)
        if (vcount != nv).any():
            rr = int(np.flatnonzero(vcount != nv)[0])
            out.append(Finding(
                "WQ001", "error", f"{context} sample {b}",
                f"row {rr}: {int(vcount[rr])} VALID steps, table says "
                f"{int(nv[rr])} visits",
                hint="every visit gets exactly one VALID step; sentinels "
                     "none"))

        # pad tail: zero flags, repeat-last row/col
        if total < S:
            tf = flags[b, total:]
            if (tf != 0).any():
                out.append(Finding(
                    "WQ001", "error", f"{context} sample {b}",
                    "pad tail carries nonzero flags",
                    hint="pad steps must be no-ops (flags 0)"))
            if (row[b, total:] != r[total - 1]).any() or \
                    (col[b, total:] != c[total - 1]).any():
                out.append(Finding(
                    "WQ001", "warning", f"{context} sample {b}",
                    "pad tail does not repeat the final step",
                    hint="repeat-last padding keeps prefetch in range"))

        # WQ002 — LPT: run visit counts non-increasing, ties by row asc
        rnv = nv[run_rows]
        dec = np.flatnonzero(rnv[1:] > rnv[:-1])
        if len(dec):
            i = int(dec[0])
            out.append(Finding(
                "WQ002", "error", f"{context} sample {b}",
                f"rows not in LPT order: run {i + 1} (row "
                f"{int(run_rows[i + 1])}, {int(rnv[i + 1])} visits) after "
                f"run {i} (row {int(run_rows[i])}, {int(rnv[i])})",
                hint="longest block-rows must schedule first"))
        ties = np.flatnonzero((rnv[1:] == rnv[:-1])
                              & (run_rows[1:] < run_rows[:-1]))
        if len(ties):
            out.append(Finding(
                "WQ002", "error", f"{context} sample {b}",
                f"unstable LPT tie-break at run {int(ties[0]) + 1}",
                hint="equal-count rows must keep ascending row order "
                     "(stable sort) for deterministic schedules"))

        # WQ003 — valid steps visit exactly the rectangular visit set
        vmask = (f & FLAG_VALID) != 0
        got = set(zip(r[vmask].tolist(), c[vmask].tolist()))
        want = set()
        for rr in range(R):
            want.update((rr, int(idx[b, rr, k]))
                        for k in range(int(nv[rr])))
        if got != want:
            extra = sorted(got - want)[:3]
            miss = sorted(want - got)[:3]
            out.append(Finding(
                "WQ003", "error", f"{context} sample {b}",
                f"queue visit set != table visit set "
                f"(missing {miss}, extra {extra})",
                hint="flattening must preserve the visit set exactly"))
    return out


# --------------------------------------------------------------------- #
# SRV00x — serve block tables vs. pool / prefix cache
# --------------------------------------------------------------------- #
def check_serve_state(pool, tables: dict, prefix=None, *,
                      extra_refs: dict[int, int] | None = None,
                      context: str = "serve") -> list[Finding]:
    """Refcount / aliasing conservation over a serve snapshot.

    ``tables`` maps a request key to its block-id list; ``prefix`` is the
    optional :class:`repro.serve.prefix.PrefixCache`; ``extra_refs``
    accounts engine-held references outside the tables (e.g. blocks
    retained for an in-flight copy-on-write).
    """
    out: list[Finding] = []
    extra_refs = extra_refs or {}
    nb = pool.num_blocks

    uses: dict[int, int] = {}
    holders: dict[int, list] = {}
    for key, blocks in tables.items():
        for bid in blocks:
            b = int(bid)
            if b < 0 or b >= nb:
                out.append(Finding(
                    "SRV003", "error", context,
                    f"request {key!r} references block {b} outside the "
                    f"pool [0, {nb})",
                    hint="table entries must be live pool block ids"))
                continue
            uses[b] = uses.get(b, 0) + 1
            holders.setdefault(b, []).append(key)

    cache_bids = set()
    if prefix is not None:
        cache_bids = set(prefix._by_key.values())

    free = list(pool._free)
    free_set = set(free)
    if len(free) != len(free_set):
        out.append(Finding(
            "SRV002", "error", context,
            "free list contains duplicate block ids",
            hint="double-free: a block was released below refcount 0"))

    for b in range(nb):
        ref = pool.refcount(b)
        expect = uses.get(b, 0) + (1 if b in cache_bids else 0) \
            + int(extra_refs.get(b, 0))
        if ref != expect:
            out.append(Finding(
                "SRV002", "error", context,
                f"block {b}: refcount {ref} != {expect} "
                f"({uses.get(b, 0)} table use(s)"
                f"{' + prefix cache' if b in cache_bids else ''}"
                f"{f' + {extra_refs[b]} engine ref(s)' if b in extra_refs else ''})",
                hint="leaked or dangling reference; check retain/release "
                     "pairing"))
        if (ref == 0) != (b in free_set):
            out.append(Finding(
                "SRV002", "error", context,
                f"block {b}: refcount {ref} but "
                f"{'on' if b in free_set else 'not on'} the free list",
                hint="free list must hold exactly the refcount-0 blocks"))
        if b in uses and b in free_set:
            out.append(Finding(
                "SRV003", "error", context,
                f"block {b} is referenced by {holders[b]!r} while on the "
                f"free list — a new allocation would corrupt live KV",
                hint="release order bug: tables must drop blocks before "
                     "they are freed"))

    # SRV001 — cross-request sharing requires a prefix-trie entry
    for b, hs in holders.items():
        if len(set(map(str, hs))) > 1 and b not in cache_bids:
            out.append(Finding(
                "SRV001", "error", context,
                f"block {b} shared by requests {sorted(map(str, hs))!r} "
                f"without a prefix-cache entry — decode writes would "
                f"cross-contaminate KV",
                hint="only prefix-cache hits may alias blocks across "
                     "requests"))
    return out
