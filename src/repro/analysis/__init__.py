"""Static plan/jaxpr/HLO verification (DESIGN.md §Static-analysis).

Three layers, no execution required:

* Layer 1 — :mod:`repro.analysis.plan_check`: host-numpy structural
  checks over planner outputs (shard plans, encodings, visit tables,
  work queues) and serve block tables.
* Layer 2 — :mod:`repro.analysis.hlo_audit`: audits lowered HLO of
  jitted step bundles against the plan's analytic comm budget.
* Layer 3 — :mod:`repro.analysis.lint`: AST rules for determinism and
  kernel-tracing failure modes.

Each layer emits :class:`repro.analysis.findings.Finding` records;
``scripts/flashcheck.py`` is the CLI driver.
"""

from repro.analysis.findings import Finding, RULES, errors, format_findings

__all__ = ["Finding", "RULES", "errors", "format_findings"]
