"""Layer 3 — AST repo lint (DESIGN.md §Static-analysis).

Rules for the failure modes PR review keeps catching by hand:

* **RNG001 / RNG002** (scoped to ``planner/``, ``dispatch/``, and
  ``autotune/``): any unseeded RNG call or set-iteration-order
  dependence breaks the ``(seed, step) -> plan`` replay purity elastic
  recovery relies on — a recovered worker must re-derive byte-identical
  plans, and a tuned config must be cache-stable across processes.
* **KER001**: Python ``if``/``while`` on traced values inside a Pallas
  kernel body silently bakes one branch into the compiled kernel (or
  fails to trace); ``@pl.when`` is the sanctioned idiom.
* **DEP001**: imports of the deprecated ``repro.core.*`` planner shims
  outside the shims themselves.
* **HYG001-003**: the hygiene subset mirrored from the ruff config
  (unused imports, mutable default args, shadowed builtins) so the tree
  stays clean even where ruff isn't installed.

Suppression: a trailing ``# noqa`` comment suppresses all rules on that
line; ``# noqa: CODE[,CODE...]`` suppresses specific ones.  Ruff's
``F401`` is honoured as an alias for HYG001 so existing re-export
annotations keep working.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.analysis.findings import Finding

__all__ = ["lint_source", "lint_paths", "default_targets"]

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)

#: ruff code -> our rule id, so one annotation silences both linters
_ALIASES = {"F401": "HYG001", "B006": "HYG002", "A001": "HYG003",
            "A002": "HYG003"}

_DEPRECATED_CORE = {"plan", "heuristic", "baselines", "ilp", "plan_exec"}

_BUILTIN_SHADOWS = {
    "list", "dict", "set", "str", "int", "float", "bool", "tuple",
    "bytes", "type", "id", "input", "sum", "min", "max", "len", "map",
    "filter", "range", "sorted", "zip", "iter", "next", "hash", "print",
    "open", "eval", "exec", "compile", "object", "slice", "format",
    "repr", "round", "abs", "pow", "vars", "dir", "any", "all",
}


def _noqa_codes(lines: list[str]) -> dict[int, set[str] | None]:
    """line no (1-based) -> suppressed codes (None = all)."""
    out: dict[int, set[str] | None] = {}
    for i, line in enumerate(lines, 1):
        m = _NOQA_RE.search(line)
        if not m:
            continue
        codes = m.group("codes")
        if codes is None:
            out[i] = None
        else:
            cs = {c.strip().upper() for c in codes.split(",") if c.strip()}
            out[i] = {_ALIASES.get(c, c) for c in cs}
    return out


def _is_seeded_rng_call(node: ast.Call) -> bool | None:
    """None if not an RNG construction/call; True seeded, False unseeded."""
    fn = node.func
    # random.<fn>(...) on the stdlib module-level (shared, process-global)
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
        mod, name = fn.value.id, fn.attr
        if mod == "random":
            if name in ("Random", "SystemRandom"):
                return bool(node.args or node.keywords) \
                    and name != "SystemRandom"
            if name == "seed":
                return True
            return False                      # random.shuffle / random.random
        if mod in ("np", "numpy"):
            return None                       # handled via np.random below
    # np.random.<fn>(...) legacy global generator
    if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Attribute):
        inner = fn.value
        if isinstance(inner.value, ast.Name) and \
                inner.value.id in ("np", "numpy") and inner.attr == "random":
            if fn.attr == "default_rng":
                return bool(node.args or node.keywords)
            if fn.attr == "seed":
                return True
            return False                      # np.random.shuffle / .rand ...
    # bare default_rng(...) (from numpy.random import default_rng)
    if isinstance(fn, ast.Name) and fn.id == "default_rng":
        return bool(node.args or node.keywords)
    return None


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and \
            isinstance(node.op, (ast.BitOr, ast.BitAnd, ast.Sub)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _iter_targets(node: ast.AST):
    """(iterated expression, line) pairs that consume iteration order."""
    if isinstance(node, (ast.For, ast.AsyncFor)):
        yield node.iter, node.lineno
    elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                           ast.GeneratorExp)):
        for gen in node.generators:
            yield gen.iter, node.lineno
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("list", "tuple", "enumerate") and node.args:
        yield node.args[0], node.lineno


def _rng_rules(tree: ast.AST, path: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            seeded = _is_seeded_rng_call(node)
            if seeded is False:
                out.append(Finding(
                    "RNG001", "error", f"{path}:{node.lineno}",
                    "unseeded (or process-global) RNG call — plans must "
                    "replay byte-identically from (seed, step)",
                    hint="thread an explicit np.random.default_rng(seed) "
                         "/ random.Random(seed) through the call"))
        for it, line in _iter_targets(node):
            if _is_set_expr(it):
                out.append(Finding(
                    "RNG002", "error", f"{path}:{line}",
                    "iteration over a set: order is hash-dependent and "
                    "varies across processes",
                    hint="wrap in sorted(...) or use a list/dict"))
    return out


# --------------------------------------------------------------------- #
# KER001 — traced-value Python branching in Pallas kernel bodies
# --------------------------------------------------------------------- #
def _kernel_functions(tree: ast.AST):
    """Functions that look like Pallas kernel bodies: >= 2 parameters
    named ``*_ref`` (the repo's kernel calling convention)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            refs = [a.arg for a in node.args.args if a.arg.endswith("_ref")]
            if len(refs) >= 2:
                yield node, set(refs)


def _traced_names(fn: ast.AST, ref_params: set[str]) -> set[str]:
    """Names holding traced values: ``*_ref`` loads, pl.load /
    pl.program_id results, and one propagation level through
    assignments/expressions of those."""
    tainted = set(ref_params)

    def expr_tainted(e: ast.AST) -> bool:
        for n in ast.walk(e):
            if isinstance(n, ast.Name) and n.id in tainted:
                return True
            if isinstance(n, ast.Call):
                f = n.func
                if isinstance(f, ast.Attribute) and \
                        isinstance(f.value, ast.Name) and \
                        f.value.id == "pl" and \
                        f.attr in ("load", "program_id", "num_programs"):
                    return True
        return False

    # two passes give one level of transitive propagation
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and expr_tainted(node.value):
                for tgt in node.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)) and \
                    node.value is not None and expr_tainted(node.value):
                if isinstance(node.target, ast.Name):
                    tainted.add(node.target.id)
    return tainted


def _kernel_rules(tree: ast.AST, path: str) -> list[Finding]:
    out = []
    for fn, refs in _kernel_functions(tree):
        tainted = _traced_names(fn, refs)

        def uses_tainted(e: ast.AST) -> bool:
            for n in ast.walk(e):
                if isinstance(n, ast.Name) and n.id in tainted:
                    return True
                if isinstance(n, ast.Subscript):
                    v = n.value
                    if isinstance(v, ast.Name) and v.id in tainted:
                        return True
            return False

        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)) and \
                    uses_tainted(node.test):
                out.append(Finding(
                    "KER001", "error", f"{path}:{node.lineno}",
                    f"Python `{'if' if isinstance(node, ast.If) else 'while'}`"
                    f" on a traced value inside kernel body "
                    f"`{fn.name}` — the branch is resolved at trace "
                    f"time, not per grid step",
                    hint="use @pl.when(cond) (or jnp.where) for "
                         "data-dependent control flow"))
    return out


# --------------------------------------------------------------------- #
# DEP001 — deprecated shim imports
# --------------------------------------------------------------------- #
def _dep_rules(tree: ast.AST, path: str) -> list[Finding]:
    norm = path.replace("\\", "/")
    if "/repro/core/" in norm or norm.endswith("repro/core"):
        return []
    out = []
    for node in ast.walk(tree):
        mods: list[tuple[str, int]] = []
        if isinstance(node, ast.Import):
            mods = [(a.name, node.lineno) for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module == "repro.core":
                mods = [(f"repro.core.{a.name}", node.lineno)
                        for a in node.names]
            else:
                mods = [(node.module, node.lineno)]
        for mod, line in mods:
            parts = mod.split(".")
            if len(parts) >= 3 and parts[:2] == ["repro", "core"] and \
                    parts[2] in _DEPRECATED_CORE:
                out.append(Finding(
                    "DEP001", "error", f"{path}:{line}",
                    f"import of deprecated shim `{mod}`",
                    hint="import from repro.planner.* instead "
                         "(plan_exec -> repro.planner.encode)"))
    return out


# --------------------------------------------------------------------- #
# HYG001-003 — hygiene subset (ruff stand-in)
# --------------------------------------------------------------------- #
def _collect_exports(tree: ast.Module) -> set[str]:
    names: set[str] = set()
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "__all__":
                for n in ast.walk(node.value):
                    if isinstance(n, ast.Constant) and \
                            isinstance(n.value, str):
                        names.add(n.value)
    return names


def _hygiene_rules(tree: ast.Module, path: str,
                   source: str) -> list[Finding]:
    out = []
    exported = _collect_exports(tree)

    # HYG001 — unused imports
    imported: list[tuple[str, str, int]] = []   # (binding, display, line)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bind = a.asname or a.name.split(".")[0]
                imported.append((bind, a.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                bind = a.asname or a.name
                imported.append((bind, f"{node.module}.{a.name}"
                                 if node.module else a.name, node.lineno))
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            root = node
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                used.add(root.id)
    # names referenced in string annotations / docstring doctests are rare
    # here; a noqa tag covers intentional side-effect imports.
    for bind, display, line in imported:
        if bind not in used and bind not in exported:
            out.append(Finding(
                "HYG001", "error", f"{path}:{line}",
                f"unused import `{display}`",
                hint="remove it, or tag `# noqa: F401` for a deliberate "
                     "re-export / side-effect import"))

    # HYG002 — mutable default args; HYG003 — shadowed builtins
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            args = node.args
            defaults = list(args.defaults) + list(args.kw_defaults)
            for dflt in defaults:
                if isinstance(dflt, (ast.List, ast.Dict, ast.Set)) or (
                        isinstance(dflt, ast.Call)
                        and isinstance(dflt.func, ast.Name)
                        and dflt.func.id in ("list", "dict", "set")):
                    name = getattr(node, "name", "<lambda>")
                    out.append(Finding(
                        "HYG002", "error", f"{path}:{dflt.lineno}",
                        f"mutable default argument in `{name}`",
                        hint="default to None and materialize inside"))
            for a in (*args.args, *args.posonlyargs, *args.kwonlyargs):
                if a.arg in _BUILTIN_SHADOWS:
                    name = getattr(node, "name", "<lambda>")
                    out.append(Finding(
                        "HYG003", "error", f"{path}:{a.lineno}",
                        f"parameter `{a.arg}` of `{name}` shadows a "
                        f"builtin",
                        hint="rename the parameter"))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id in _BUILTIN_SHADOWS:
                    out.append(Finding(
                        "HYG003", "error", f"{path}:{node.lineno}",
                        f"assignment shadows builtin `{t.id}`",
                        hint="rename the variable"))
    return out


# --------------------------------------------------------------------- #
# driver
# --------------------------------------------------------------------- #
def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint one module's source.  ``path`` scopes the path-dependent
    rules (RNG in planner//dispatch//autotune/, DEP outside repro/core/) and
    prefixes locations."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("HYG001", "error", f"{path}:{e.lineno or 0}",
                        f"syntax error: {e.msg}",
                        hint="file does not parse")]
    norm = path.replace("\\", "/")
    findings: list[Finding] = []
    if "/planner/" in norm or "/dispatch/" in norm \
            or "/autotune/" in norm:
        findings += _rng_rules(tree, path)
    findings += _kernel_rules(tree, path)
    findings += _dep_rules(tree, path)
    findings += _hygiene_rules(tree, path, source)

    noqa = _noqa_codes(source.splitlines())
    kept = []
    for f in findings:
        line = 0
        if ":" in f.location:
            tail = f.location.rsplit(":", 1)[-1]
            line = int(tail) if tail.isdigit() else 0
        codes = noqa.get(line, ...)
        if codes is ... or (codes is not None and f.rule not in codes):
            kept.append(f)
    kept.sort(key=lambda f: f.location)
    return kept


def default_targets(root: Path) -> list[Path]:
    """The lint closure: every python file under src/ scripts/
    benchmarks/ tests/ examples/."""
    out: list[Path] = []
    for sub in ("src", "scripts", "benchmarks", "tests", "examples"):
        d = root / sub
        if d.is_dir():
            out.extend(sorted(d.rglob("*.py")))
    return out


def lint_paths(paths, root: Path | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for p in paths:
        p = Path(p)
        rel = str(p.relative_to(root)) if root and p.is_absolute() else str(p)
        findings += lint_source(p.read_text(), rel)
    return findings
