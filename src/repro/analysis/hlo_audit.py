"""Layer 2 — lowered-program audit (DESIGN.md §Static-analysis).

Audits the partitioned HLO text of a jitted step bundle against the
plan's *analytic* communication budget, with no execution: every
collective the program runs must be one the plan predicted (kind and
volume), and the program must be free of the classic silent-perf killers
— sharding-propagation full gathers, f64 upcasts, host transfers,
non-donated hot-loop buffers.

Built on :func:`repro.launch.hlo_analysis.collect_collectives`, which
rolls per-instruction wire bytes through ``while`` trip counts, so a
collective inside a scan-over-layers loop is charged once per trip.

Rule ids: HLO101-HLO106.
"""

from __future__ import annotations

import dataclasses
import re

from repro.analysis.findings import Finding
from repro.core.workload import comm_bytes
from repro.launch.hlo_analysis import collect_collectives

__all__ = ["CommBudget", "kv_exchange_budget", "audit_collectives",
           "audit_numerics", "audit_host_transfers", "audit_donation",
           "audit_program", "collective_totals"]


@dataclasses.dataclass(frozen=True)
class CommBudget:
    """Analytic per-device wire-byte caps, by collective kind.

    ``allowed`` maps an HLO collective kind ("all-gather",
    "collective-permute", ...) to the maximum total wire bytes the plan
    predicts for it; a kind absent from the map is *forbidden* (HLO101).
    ``slack`` is the fractional tolerance on the caps (compiler rounding,
    layout padding).  ``full_gather_bytes``: if set, any single
    all-gather whose result is at least this size trips HLO103 even when
    all-gathers are budgeted — the signature of sharding propagation
    re-materializing a tensor the plan meant to keep sharded.
    """

    allowed: dict[str, float]
    slack: float = 0.01
    full_gather_bytes: float | None = None
    note: str = ""


def kv_exchange_budget(buf_len: int, num_workers: int, kv_heads: int,
                       head_dim: int, *, dtype_bytes: int = 2,
                       fwd_and_bwd: bool = False, overlap: str = "chunked",
                       batch: int = 1, layers: int = 1,
                       slack: float = 0.01,
                       extra: dict[str, float] | None = None) -> CommBudget:
    """The attention KV exchange's analytic budget (Eq.4/Eq.5 outer).

    ``buf_len`` is the *static* per-rank exchange size — the Eq.5 pow2
    bucket for flashcp (:attr:`PlanEncoding.buf_len`), ``C / N`` for the
    full-exchange baselines.  The device moves exactly this (the paper's
    single continuous communication buffer), so the audited wire bytes
    must match :func:`repro.core.workload.comm_bytes` on it to within
    ``slack`` — the chunked ppermute rotation (N-1 hops of one buffer)
    and the blocking all-gather ((N-1)/N of N buffers) both reduce to the
    same total.

    The plan metadata riding the exchange (int32 doc + pos per buffer
    slot) is budgeted alongside the K/V payload on the same kind.
    ``batch`` and ``layers`` scale the budget to per-device sample count
    and attention-layer count (every attention layer runs its own
    exchange in a full step program); ``extra`` admits additional kinds
    (e.g. gradient all-reduce for a full train step).
    """
    mult = batch * layers
    payload = mult * comm_bytes(buf_len, num_workers, kv_heads, head_dim,
                                dtype_bytes=dtype_bytes,
                                fwd_and_bwd=fwd_and_bwd)
    # doc + pos: two int32 streams with the same (buf, N-1) geometry —
    # comm_bytes' leading "K and V" factor 2 counts exactly the pair.
    # Only the chunked rotation moves them, exactly once per program
    # (forward only — the indices are fwd residuals, not re-exchanged in
    # the backward pass, and the rotation is shared across layers); the
    # blocking layout reads the host-replicated copies.
    meta = batch * comm_bytes(buf_len, num_workers, 1, 1, dtype_bytes=4,
                              fwd_and_bwd=False) \
        if overlap == "chunked" else 0
    kind = "collective-permute" if overlap == "chunked" else "all-gather"
    allowed = {kind: float(payload + meta)}
    for k, v in (extra or {}).items():
        allowed[k] = allowed.get(k, 0.0) + v
    return CommBudget(allowed=allowed, slack=slack,
                      note=f"kv-exchange {kind} buf_len={buf_len}")


def collective_totals(text: str) -> dict[str, float]:
    """Total wire bytes per collective kind, trip-count-aware."""
    totals: dict[str, float] = {}
    for c in collect_collectives(text):
        totals[c.kind] = totals.get(c.kind, 0.0) + c.wire_bytes * c.trips
    return totals


def audit_collectives(text: str, budget: CommBudget, *,
                      context: str = "hlo") -> list[Finding]:
    """HLO101/HLO102/HLO103 — diff the program's collectives against the
    analytic budget."""
    out: list[Finding] = []
    colls = collect_collectives(text)
    totals: dict[str, float] = {}
    biggest: dict[str, object] = {}
    for c in colls:
        totals[c.kind] = totals.get(c.kind, 0.0) + c.wire_bytes * c.trips
        if c.kind not in biggest or \
                c.wire_bytes > biggest[c.kind].wire_bytes:
            biggest[c.kind] = c

    for kind, tot in sorted(totals.items()):
        cap = budget.allowed.get(kind)
        top = biggest[kind]
        if cap is None:
            out.append(Finding(
                "HLO101", "error", context,
                f"unpredicted collective kind `{kind}`: {tot:.3g} wire "
                f"bytes the plan's comm budget does not account for "
                f"(largest: {top.var} in {top.computation}, "
                f"{top.result_bytes} result bytes x{top.trips:g})",
                hint="redundant KV communication or stray collective — "
                     "the plan predicted none of this kind (Eq.5)"))
        elif tot > cap * (1.0 + budget.slack):
            out.append(Finding(
                "HLO102", "error", context,
                f"`{kind}` moves {tot:.6g} wire bytes, analytic budget "
                f"{cap:.6g} (+{budget.slack:.0%} slack) "
                f"[{budget.note}]".rstrip(" []"),
                hint="the lowered exchange exceeds the plan's Eq.4/Eq.5 "
                     "volume — check sharding specs and bucket sizes"))

    if budget.full_gather_bytes is not None:
        for c in colls:
            if c.kind == "all-gather" and \
                    c.result_bytes >= budget.full_gather_bytes:
                out.append(Finding(
                    "HLO103", "error", context,
                    f"full-size all-gather {c.var} in {c.computation}: "
                    f"{c.result_bytes} result bytes (threshold "
                    f"{budget.full_gather_bytes:.6g}) x{c.trips:g} trips",
                    hint="sharding propagation re-gathered a tensor the "
                         "plan keeps sharded; pin its PartitionSpec"))
    return out


_F64_RE = re.compile(r"\bf64\[")


def audit_numerics(text: str, *, context: str = "hlo") -> list[Finding]:
    """HLO104 — f64 anywhere in the module (CPU sharding or an unguarded
    numpy scalar silently upcasting the step to double)."""
    out: list[Finding] = []
    hits = []
    for i, line in enumerate(text.splitlines(), 1):
        if _F64_RE.search(line):
            hits.append((i, line.strip()[:100]))
    if hits:
        i, frag = hits[0]
        out.append(Finding(
            "HLO104", "error", context,
            f"{len(hits)} f64-typed instruction(s); first at module line "
            f"{i}: `{frag}`",
            hint="an f32->f64 upcast doubles memory traffic; find the "
                 "float64 constant/np scalar leaking into the trace"))
    return out


_HOST_OPCODES = ("infeed", "outfeed")
_CALLBACK_RE = re.compile(
    r'custom_call_target="[^"]*(callback|host)[^"]*"', re.I)


def audit_host_transfers(text: str, *,
                         context: str = "hlo") -> list[Finding]:
    """HLO105 — infeed/outfeed, host send/recv, python callbacks."""
    out: list[Finding] = []
    for i, line in enumerate(text.splitlines(), 1):
        s = line.strip()
        hit = None
        for opc in _HOST_OPCODES:
            if re.search(rf"\b{opc}\(", s):
                hit = opc
        if re.search(r"\b(send|recv)\(", s) and \
                "is_host_transfer=true" in s:
            hit = "host send/recv"
        if _CALLBACK_RE.search(s):
            hit = "host callback custom-call"
        if hit:
            out.append(Finding(
                "HLO105", "error", f"{context}:{i}",
                f"host transfer in the step program ({hit}): "
                f"`{s[:100]}`",
                hint="host round-trips serialize the device stream; move "
                     "the logic into the traced program or off the hot "
                     "loop"))
            if len(out) >= 8:
                break
    return out


_ALIAS_PAIR_RE = re.compile(r"\(\s*(\d+)\s*,")


def _alias_map_body(text: str) -> str:
    """The brace-balanced body of the module's ``input_output_alias={...}``
    attribute (nested ``{}`` inside alias entries defeats a non-greedy
    regex)."""
    start = text.find("input_output_alias={")
    if start < 0:
        return ""
    i = text.index("{", start)
    depth = 0
    for j in range(i, min(len(text), i + 100_000)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                return text[i + 1: j]
    return ""
_ENTRY_PARAM_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\])[^\s]*)\s+parameter\((\d+)\)")


def _entry_param_bytes(text: str) -> dict[int, int]:
    """param number -> result bytes, from the ENTRY computation body."""
    from repro.launch.hlo_analysis import _type_bytes
    params: dict[int, int] = {}
    in_entry = False
    for raw in text.splitlines():
        if raw.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry and raw.startswith("}"):
            break
        if not in_entry:
            continue
        m = _ENTRY_PARAM_RE.search(raw)
        if m:
            params[int(m.group(2))] = _type_bytes(m.group(1))
    return params


def audit_donation(text: str, *, min_bytes: int = 1 << 20,
                   expect_params=None,
                   context: str = "hlo") -> list[Finding]:
    """HLO106 — large entry parameters not aliased to an output.

    ``expect_params``: parameter numbers the step builder donated
    (``donate_argnums``-derived) — each must appear in the module's
    ``input_output_alias``; a miss is an error (the donation silently
    fell off, doubling peak memory).  Without it, any non-aliased
    parameter of at least ``min_bytes`` is reported as a warning.
    """
    out: list[Finding] = []
    aliased = {int(p)
               for p in _ALIAS_PAIR_RE.findall(_alias_map_body(text))}
    params = _entry_param_bytes(text)

    if expect_params is not None:
        for p in sorted(set(expect_params)):
            if p not in aliased:
                out.append(Finding(
                    "HLO106", "error", context,
                    f"entry parameter {p} "
                    f"({params.get(p, 0)} bytes) was donated by the step "
                    f"builder but is not in input_output_alias",
                    hint="donation fell off (shape/dtype mismatch between "
                         "donated input and outputs?) — peak memory "
                         "doubles"))
        return out

    for p, nbytes in sorted(params.items()):
        if nbytes >= min_bytes and p not in aliased:
            out.append(Finding(
                "HLO106", "warning", context,
                f"large entry parameter {p} ({nbytes} bytes) is not "
                f"donated",
                hint="if this buffer is dead after the step (params, opt "
                     "state, KV cache), donate it"))
    return out


def audit_program(text: str, budget: CommBudget | None = None, *,
                  donate_expect=None, donate_min_bytes: int = 1 << 20,
                  context: str = "hlo") -> list[Finding]:
    """All Layer-2 rules over one lowered module."""
    out: list[Finding] = []
    if budget is not None:
        out += audit_collectives(text, budget, context=context)
    out += audit_numerics(text, context=context)
    out += audit_host_transfers(text, context=context)
    out += audit_donation(text, expect_params=donate_expect,
                          min_bytes=donate_min_bytes, context=context)
    return out
