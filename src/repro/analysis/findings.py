"""Structured findings shared by all three analysis layers.

A :class:`Finding` is one violation (or advisory) tied to a rule id from
the :data:`RULES` registry.  Rule ids are stable and documented in
DESIGN.md §Static-analysis — tests and CI key on them, so adding a rule
means adding a registry entry (and a DESIGN.md row), never renaming one.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Finding", "RULES", "errors", "format_findings"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structural violation.

    ``location`` is a ``file:line`` reference for lint findings and a
    human-readable context string ("plan cp=4 arch=llama3_70b", "queue
    row 3") for plan/HLO findings.
    """

    rule: str                 # registry key, e.g. "PLAN001"
    severity: str             # "error" | "warning"
    location: str
    message: str
    hint: str = ""            # one-line suggested fix

    def __post_init__(self) -> None:
        assert self.rule in RULES, f"unregistered rule id: {self.rule}"
        assert self.severity in ("error", "warning"), self.severity

    def render(self) -> str:
        sev = self.severity.upper()
        out = f"{sev} {self.rule} [{self.location}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


#: rule id -> one-line invariant.  The prose expansion (origin bug, fix
#: guidance) lives in DESIGN.md §Static-analysis.
RULES: dict[str, str] = {
    # --- Layer 1: shard plans -------------------------------------- #
    "PLAN001": "every document token is covered exactly once, in order",
    "PLAN002": "shard doc/worker ids and lengths are in range and positive",
    "PLAN003": "equal-token constraint (Eq.2): each rank holds C/N tokens",
    "PLAN004": "per-rank workload imbalance within the declared bound",
    # --- Layer 1: plan encodings ----------------------------------- #
    "ENC001": "perm is an exact permutation of packed token positions",
    "ENC002": "encoded doc/pos agree with the plan's shard layout",
    "ENC003": "causal closure: every KV a query attends to is local or gathered",
    "ENC004": "no redundant KV exchange: only non-last shard tokens are sent (Eq.5)",
    "ENC005": "every non-last shard token is sent (completeness of Eq.4/5)",
    # --- Layer 1: visit tables ------------------------------------- #
    "TAB001": "visit tables are sound vs. the dense per-token visibility oracle",
    "TAB002": "visit-table indices are in range with -1/-2 padding discipline",
    # --- Layer 1: work queues -------------------------------------- #
    "WQ001": "work-queue FIRST/LAST/VALID flags are well-formed per row",
    "WQ002": "work-queue rows are in LPT order (stable ties)",
    "WQ003": "flat queue visits exactly the rectangular grid's visit set",
    # --- Layer 1: serve block tables ------------------------------- #
    "SRV001": "no cross-request block aliasing without a prefix-trie entry",
    "SRV002": "block refcounts conserve against table uses + cache + free list",
    "SRV003": "block-table entries are valid pool block ids",
    # --- Layer 1: autotune search space ----------------------------- #
    "TUNE001": "candidate enumeration is deterministic, sorted, and deduplicated",
    "TUNE002": "every enumerated candidate passes its own admissibility predicate",
    # --- Layer 2: HLO audit ---------------------------------------- #
    "HLO101": "no collective kind the plan's comm budget didn't predict",
    "HLO102": "per-kind collective bytes within the analytic comm budget",
    "HLO103": "no unintended full KV all-gather from sharding propagation",
    "HLO104": "no f64 values or f32->f64 upcasts in the step program",
    "HLO105": "no host transfers (infeed/outfeed/send/recv/host callbacks)",
    "HLO106": "large hot-loop buffers are donated (input_output_alias)",
    # --- Layer 3: repo lint ---------------------------------------- #
    "RNG001": "no unseeded RNG in planner/, dispatch/, or autotune/ (replay purity)",
    "RNG002": "no set-iteration-order dependence in planner/dispatch/autotune",
    "KER001": "no traced-value Python branching in Pallas kernel bodies",
    "DEP001": "no imports of deprecated repro.core.* shims outside the shims",
    "HYG001": "no unused imports",
    "HYG002": "no mutable default arguments",
    "HYG003": "no shadowed builtins in assignments or parameters",
}


def errors(findings: list[Finding]) -> list[Finding]:
    """The error-severity subset (what makes flashcheck exit nonzero)."""
    return [f for f in findings if f.severity == "error"]


def format_findings(findings: list[Finding]) -> str:
    if not findings:
        return "clean: no findings"
    lines = [f.render() for f in findings]
    n_err = len(errors(findings))
    n_warn = len(findings) - n_err
    lines.append(f"-- {n_err} error(s), {n_warn} warning(s)")
    return "\n".join(lines)
