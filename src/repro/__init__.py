"""repro: FlashCP — load-balanced, communication-efficient context
parallelism for LLM training, as a production-grade JAX framework.

Subpackages:
  core       — the paper's contribution (planner, sharding-aware comm, CP
               attention islands)
  kernels    — Pallas TPU doc-masked flash attention (+ ref oracle)
  models     — dense/MoE/hybrid/SSM/audio/VLM decoder zoo
  data       — packing + dataset length distributions + pipeline
  optim      — AdamW, schedules, clipping, gradient compression
  checkpoint — atomic async resharding checkpoints
  runtime    — sharding rules, fault tolerance, elastic, straggler
  configs    — the 10 assigned architectures
  launch     — mesh, dry-run, train, serve
"""

__version__ = "1.0.0"
