"""JAX version compatibility helpers.

The mesh APIs moved between JAX releases:

* ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of
  ``jax.make_mesh`` only exist on newer JAX (>= 0.5.x); on 0.4.x meshes
  are constructed without axis types.
* ``jax.set_mesh`` (and its predecessor ``jax.sharding.use_mesh``) do not
  exist on 0.4.x, where entering the ``Mesh`` context manager is the way
  to install a global mesh.
* ``jax.sharding.AbstractMesh`` takes ``(axis_sizes, axis_names)`` on new
  JAX but a single ``((name, size), ...)`` tuple on 0.4.x.

Every mesh construction / installation in this repo goes through these
helpers so the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import contextlib
from typing import Sequence

import jax

__all__ = ["make_mesh", "set_mesh", "make_abstract_mesh", "shard_map",
           "axis_size"]


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis inside a shard_map island.

    ``jax.lax.axis_size`` only exists on newer JAX; on 0.4.x
    ``jax.core.axis_frame(name)`` resolves to the (static) size.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.core as jc
    return int(jc.axis_frame(axis_name))

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # moved out of jax.experimental (and check_rep -> check_vma) later
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f=None, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if f is None:
            return lambda g: _shard_map_old(g, **kwargs)
        return _shard_map_old(f, **kwargs)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _HAS_AXIS_TYPE:
        kwargs["axis_types"] = \
            (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Prefers ``jax.set_mesh`` (new), then ``jax.sharding.use_mesh``, and
    falls back to the classic ``Mesh`` context manager on 0.4.x.
    """
    if hasattr(jax, "set_mesh"):
        ctx = jax.set_mesh(mesh)
        # jax.set_mesh is a context manager on recent versions; on some
        # intermediates it sets state and returns None.
        return ctx if ctx is not None else contextlib.nullcontext(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on 0.4.x


def make_abstract_mesh(axis_shapes: Sequence[int],
                       axis_names: Sequence[str]):
    """Device-free mesh for sharding-rule metadata (no allocation)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(tuple(axis_names), tuple(axis_shapes))))
