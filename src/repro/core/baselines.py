"""Legacy import path — baseline planners live in
:mod:`repro.planner.baselines`; resolve by name via
:func:`repro.planner.get_planner`."""

from repro.planner.baselines import (BASELINE_PLANNERS,  # noqa: F401
                                     contiguous_plan, llama3_plan,
                                     per_doc_plan, ring_zigzag_plan)

__all__ = ["llama3_plan", "per_doc_plan", "ring_zigzag_plan",
           "contiguous_plan", "BASELINE_PLANNERS"]
