"""Legacy import path — baseline planners live in
:mod:`repro.planner.baselines`; resolve by name via
:func:`repro.planner.get_planner`."""

import warnings

warnings.warn(
    "repro.core.baselines is deprecated; import from repro.planner.baselines instead",
    DeprecationWarning, stacklevel=2)

from repro.planner.baselines import (BASELINE_PLANNERS,  # noqa: F401
                                     contiguous_plan, llama3_plan,
                                     per_doc_plan, ring_zigzag_plan)

__all__ = ["llama3_plan", "per_doc_plan", "ring_zigzag_plan",
           "contiguous_plan", "BASELINE_PLANNERS"]
