"""Baseline CP sharding plans (paper §4.1): Llama3 CP, Per-Doc CP, Ring-Attn.

All baselines are expressed as :class:`~repro.core.plan.ShardingPlan`s over
the *same* substrate as FlashCP so that the paper's comparisons (Fig. 5/6/7)
run on identical machinery; only the plan and the communication style differ.

* ``llama3_plan``   — Per-Seq sharding: the packed sequence is split into
  2N equal chunks regardless of document boundaries (zigzag pairing i and
  2N-1-i, Fig. 1(b)); full-KV all-gather (Eq. 4).  Workload-imbalanced under
  document masking.
* ``per_doc_plan``  — every document is zigzag-split into 2N chunks
  (WLB-LLM); balanced but kernel-inefficient; full-KV all-gather (Eq. 4).
* ``ring_zigzag_plan`` — same shard layout as Per-Doc, but KV travels by
  P2P ring (``comm_style='ring'``): N-1 ``ppermute`` hops of the full local
  KV, attention computed blockwise with LSE accumulation.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .heuristic import zigzag_doc_shards
from .plan import Shard, ShardingPlan, merge_adjacent_shards, validate_plan

__all__ = ["llama3_plan", "per_doc_plan", "ring_zigzag_plan", "BASELINE_PLANNERS"]


def _doc_of_position(doc_lens: np.ndarray):
    """Map a global packed position -> (doc_id, offset_in_doc)."""
    bounds = np.concatenate([[0], np.cumsum(doc_lens)])
    return bounds


def llama3_plan(doc_lens: Sequence[int], num_workers: int,
                *, validate: bool = True) -> ShardingPlan:
    """Per-Seq sharding: 2N uniform chunks of the packed sequence, worker i
    receives chunks i and 2N-1-i.  Document boundaries are ignored, so a
    chunk may contain pieces of several documents (each piece becomes a
    Shard of its own document)."""
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    ctx = int(doc_lens.sum())
    n2 = 2 * num_workers
    assert ctx % n2 == 0, f"context {ctx} must divide 2N={n2} for Llama3 CP"
    chunk = ctx // n2
    bounds = _doc_of_position(doc_lens)

    shards: list[Shard] = []
    for c in range(n2):
        worker = c if c < num_workers else n2 - 1 - c
        lo, hi = c * chunk, (c + 1) * chunk
        # walk documents overlapping [lo, hi)
        first = int(np.searchsorted(bounds, lo, side="right")) - 1
        pos = lo
        d = first
        while pos < hi:
            doc_end = int(bounds[d + 1])
            take = min(hi, doc_end) - pos
            shards.append(Shard(doc_id=d, start=int(pos - bounds[d]),
                                length=int(take), worker=worker))
            pos += take
            d += 1
    shards = merge_adjacent_shards(shards)
    plan = ShardingPlan(doc_lens=doc_lens, shards=shards,
                        num_workers=num_workers, comm_style="allgather")
    if validate:
        validate_plan(plan)
    return plan


def per_doc_plan(doc_lens: Sequence[int], num_workers: int,
                 *, validate: bool = True) -> ShardingPlan:
    """Per-Doc CP (WLB-LLM): zigzag-shard every document independently."""
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    shards: list[Shard] = []
    for did, d in enumerate(doc_lens):
        shards.extend(zigzag_doc_shards(did, int(d), num_workers))
    plan = ShardingPlan(doc_lens=doc_lens, shards=shards,
                        num_workers=num_workers, comm_style="allgather")
    if validate:
        # zigzag remainders can leave ±1-token differences between workers;
        # Per-Doc CP in practice pads documents — we only require coverage.
        validate_plan(plan, require_equal_tokens=False)
    return plan


def ring_zigzag_plan(doc_lens: Sequence[int], num_workers: int,
                     *, validate: bool = True) -> ShardingPlan:
    """Ring-Attn (Zigzag): Per-Doc layout with ring P2P communication."""
    plan = per_doc_plan(doc_lens, num_workers, validate=validate)
    plan.comm_style = "ring"
    return plan


def contiguous_plan(doc_lens: Sequence[int], num_workers: int,
                    *, validate: bool = True) -> ShardingPlan:
    """Contiguous N-chunk sharding with FlashCP's sharding-aware comm.

    Used for recurrent architectures (Jamba's Mamba layers, xLSTM): SSM
    state must flow rank i -> i+1, so token order must be preserved across
    ranks.  FlashCP's communication mechanism still applies (documents
    wholly inside one chunk are never exchanged; only non-last doc pieces
    are), but Whole-Doc *placement* is constrained by the ordering —
    recorded in DESIGN.md §Arch-applicability.
    """
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    ctx = int(doc_lens.sum())
    assert ctx % num_workers == 0
    chunk = ctx // num_workers
    bounds = _doc_of_position(doc_lens)

    shards: list[Shard] = []
    for j in range(num_workers):
        lo, hi = j * chunk, (j + 1) * chunk
        first = int(np.searchsorted(bounds, lo, side="right")) - 1
        pos, d = lo, first
        while pos < hi:
            doc_end = int(bounds[d + 1])
            take = min(hi, doc_end) - pos
            shards.append(Shard(doc_id=d, start=int(pos - bounds[d]),
                                length=int(take), worker=j))
            pos += take
            d += 1
    shards = merge_adjacent_shards(shards)
    plan = ShardingPlan(doc_lens=doc_lens, shards=shards,
                        num_workers=num_workers, comm_style="flashcp")
    if validate:
        validate_plan(plan)
    return plan


def _flashcp_adapter(doc_lens, num_workers, *, validate=True):
    from .heuristic import flashcp_plan

    plan, _ = flashcp_plan(doc_lens, num_workers, validate=validate)
    return plan


#: name -> planner fn, used by benchmarks and the training launcher
BASELINE_PLANNERS = {
    "llama3": llama3_plan,
    "per_doc": per_doc_plan,
    "ring_zigzag": ring_zigzag_plan,
    "ring": ring_zigzag_plan,
    "contiguous": contiguous_plan,
    "flashcp": _flashcp_adapter,
}
