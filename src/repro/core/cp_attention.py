"""Device-side context parallelism: shard_map islands over the CP axis.

Four communication strategies, all on identical substrate (so the paper's
comparisons are apples-to-apples):

* ``flashcp`` / ``contiguous`` — **sharding-aware communication** (§3.2):
  each rank gathers only the compacted non-last-shard KV buffer (Eq. 5
  volume).  The backward pass is the JAX transpose of the gather — a
  reduce-scatter of dKV with the same reduced volume (the paper's 4x
  factor).
* ``allgather`` — full-KV exchange (Eq. 4): Llama3 CP and Per-Doc CP.
* ``ring`` — Ring-Attention (Zigzag): N-1 ``ppermute`` hops of full local
  KV with blockwise attention + online LSE merge (compute/comm overlap via
  the XLA latency-hiding scheduler on the ppermute chain).

A self-ownership subtlety of the compact buffer: the all-gather includes
this rank's own contribution, which is *also* present as local KV.  The
island marks its own gathered segment invisible (doc id -2) so no KV pair
is double-counted.

The SSM island implements cross-rank recurrence for Mamba/xLSTM: local
chunked scans + an all-gather of per-rank (decay, state) summaries with an
associative prefix combine — O(state) communication, no serialization
across ranks.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import axis_size, shard_map
from jax.sharding import PartitionSpec as P

from repro.models.context import ExecContext, local_ssm_scan

__all__ = ["make_cp_context", "CP_AXIS"]

CP_AXIS = "model"
NEG = -1e30


# ===================================================================== #
# helpers
# ===================================================================== #
def _take_tokens(x, idx):
    """x (b, H, T, D); idx (b, S) with -1 padding -> (b, H, S, D), zeroed
    at padding."""
    safe = jnp.maximum(idx, 0)[:, None, :, None]
    out = jnp.take_along_axis(x, safe, axis=2)
    return out * (idx >= 0)[:, None, :, None].astype(x.dtype)


def _partial_attention(q, k, v, q_doc, q_pos, kv_doc, kv_pos, scale,
                       q_chunk: int):
    """Unnormalized blockwise attention: returns (o, m, l) for LSE merging.

    o (b,Hq,T,D) f32 = sum_s exp(s - m) v;  m rowmax;  l rowsum.
    """
    b, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if T % q_chunk != 0:
        q_chunk = T
    nq = T // q_chunk

    def one(args):
        qc, qd, qp = args
        qc = qc.astype(jnp.float32).reshape(b, Hkv, G, q_chunk, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kf) * scale
        vis = (qd[:, :, None] == kv_doc[:, None, :]) \
            & (qp[:, :, None] >= kv_pos[:, None, :]) \
            & (qd[:, :, None] >= 0) & (kv_doc[:, None, :] >= 0)
        s = jnp.where(vis[:, None, None], s, NEG)
        m = jnp.max(s, axis=-1)                                  # (b,Hkv,G,qc)
        p = jnp.where(vis[:, None, None], jnp.exp(s - m[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        return (o.reshape(b, Hq, q_chunk, D), m.reshape(b, Hq, q_chunk),
                l.reshape(b, Hq, q_chunk))

    if nq == 1:
        return one((q, q_doc, q_pos))
    qs = q.reshape(b, Hq, nq, q_chunk, D).transpose(2, 0, 1, 3, 4)
    qds = q_doc.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    qps = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    os, ms, ls = jax.lax.map(one, (qs, qds, qps))
    return (os.transpose(1, 2, 0, 3, 4).reshape(b, Hq, T, D),
            ms.transpose(1, 2, 0, 3).reshape(b, Hq, T),
            ls.transpose(1, 2, 0, 3).reshape(b, Hq, T))


def _masked_attention(q, k, v, q_doc, q_pos, kv_doc, kv_pos, *, impl,
                      q_chunk, interpret, tables=None, block_q=128,
                      block_k=128):
    from repro.kernels import ops as kops

    if impl == "pallas":
        assert tables is not None, "pallas CP attention needs host tables"
        return kops.doc_flash_attention(q, k, v, q_doc, q_pos, kv_doc,
                                        kv_pos, tables, interpret=interpret,
                                        block_q=block_q, block_k=block_k)
    return kops.doc_attention_xla(q, k, v, q_doc, q_pos, kv_doc, kv_pos,
                                  q_chunk=q_chunk)


# ===================================================================== #
# islands
# ===================================================================== #
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _quantized_gather(x, axis_name):
    """int8 all-gather with per-(batch, head, token) scales — beyond-paper
    comm compression of the Eq. 5 KV exchange (EXPERIMENTS.md §Perf #6).

    Straight-through backward: ``round`` has zero gradient, so the VJP is
    defined explicitly as the transpose of a plain gather — a full-precision
    reduce-scatter of dKV (gradients stay exact; only the forward KV wire
    is quantized)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                  127).astype(jnp.int8)
    g8 = jax.lax.all_gather(q8, axis_name, axis=2, tiled=True)
    gs = jax.lax.all_gather(scale.astype(jnp.float32), axis_name, axis=2,
                            tiled=True)
    return (g8.astype(jnp.float32) * gs).astype(x.dtype)


def _quantized_gather_fwd(x, axis_name):
    return _quantized_gather(x, axis_name), None


def _quantized_gather_bwd(axis_name, _, g):
    return (jax.lax.psum_scatter(g, axis_name, scatter_dimension=2,
                                 tiled=True),)


_quantized_gather.defvjp(_quantized_gather_fwd, _quantized_gather_bwd)


def _flashcp_island(q, k, v, doc, pos, send_idx, gath_doc, gath_pos,
                    *, impl, q_chunk, interpret, tables=None, block_q=128,
                    block_k=128, kv_comm_dtype="native"):
    b = q.shape[0]
    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)
    buf = send_idx.shape[-1]

    sidx = send_idx[:, 0]                       # (b, buf)
    ksel = _take_tokens(k, sidx)
    vsel = _take_tokens(v, sidx)
    if kv_comm_dtype == "int8":
        kg = _quantized_gather(ksel, CP_AXIS)
        vg = _quantized_gather(vsel, CP_AXIS)
    else:
        kg = jax.lax.all_gather(ksel, CP_AXIS, axis=2, tiled=True)
        vg = jax.lax.all_gather(vsel, CP_AXIS, axis=2, tiled=True)

    # hide my own gathered segment (those tokens are already local KV)
    seg = jnp.arange(N * buf, dtype=jnp.int32) // buf
    gdoc = jnp.where((seg == me)[None, :], -2, gath_doc)

    kv_k = jnp.concatenate([k, kg], axis=2)
    kv_v = jnp.concatenate([v, vg], axis=2)
    kv_doc = jnp.concatenate([doc, gdoc], axis=1)
    kv_pos = jnp.concatenate([pos, gath_pos], axis=1)

    tabs = None
    if tables is not None:
        tabs = tuple(t[:, 0] if t.ndim > 2 and t.shape[1] == 1 else t
                     for t in tables)
    return _masked_attention(q, kv_k, kv_v, doc, pos, kv_doc, kv_pos,
                             impl=impl, q_chunk=q_chunk, interpret=interpret,
                             tables=tabs, block_q=block_q, block_k=block_k)


def _allgather_island(q, k, v, doc, pos, *, impl, q_chunk, interpret):
    kg = jax.lax.all_gather(k, CP_AXIS, axis=2, tiled=True)
    vg = jax.lax.all_gather(v, CP_AXIS, axis=2, tiled=True)
    gdoc = jax.lax.all_gather(doc, CP_AXIS, axis=1, tiled=True)
    gpos = jax.lax.all_gather(pos, CP_AXIS, axis=1, tiled=True)
    return _masked_attention(q, kg, vg, doc, pos, gdoc, gpos, impl=impl,
                             q_chunk=q_chunk, interpret=interpret)


def _ring_island(q, k, v, doc, pos, *, q_chunk, scale):
    b, Hq, T, D = q.shape
    N = axis_size(CP_AXIS)
    perm = [(i, (i + 1) % N) for i in range(N)]

    acc = jnp.zeros((b, Hq, T, D), jnp.float32)
    m = jnp.full((b, Hq, T), NEG, jnp.float32)
    l = jnp.zeros((b, Hq, T), jnp.float32)

    def step(carry, _):
        kc, vc, dc, pc, acc, m, l = carry
        o_i, m_i, l_i = _partial_attention(q, kc, vc, doc, pos, dc, pc,
                                           scale, q_chunk)
        m_new = jnp.maximum(m, m_i)
        c1 = jnp.exp(m - m_new)
        c2 = jnp.exp(m_i - m_new)
        acc = acc * c1[..., None] + o_i * c2[..., None]
        l = l * c1 + l_i * c2
        kc = jax.lax.ppermute(kc, CP_AXIS, perm)
        vc = jax.lax.ppermute(vc, CP_AXIS, perm)
        dc = jax.lax.ppermute(dc, CP_AXIS, perm)
        pc = jax.lax.ppermute(pc, CP_AXIS, perm)
        return (kc, vc, dc, pc, acc, m_new, l), None

    (kc, vc, dc, pc, acc, m, l), _ = jax.lax.scan(
        step, (k, v, doc, pos, acc, m, l), None, length=N)
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30),
                    0.0)
    return out.astype(q.dtype)


def _moe_island(x, topi, gates, wi, wg, wo, *, kind, capacity_factor,
                top_k):
    """Expert-parallel dispatch: local capacity-clipped routing buffers
    exchanged with all-to-all over the ``model`` axis (experts are sharded
    over that axis), expert FFN on owned experts, all-to-all back, local
    weighted combine."""
    from repro.models.moe import (capacity, combine_local, dispatch_local,
                                  expert_ffn)

    b, t, d = x.shape
    N = axis_size(CP_AXIS)
    E_local = wi.shape[0]
    E = E_local * N
    n = b * t
    cap = capacity(n, E, top_k, capacity_factor)

    buf, slot, tok_s, gat_s, keep = dispatch_local(
        x.reshape(n, d), topi.reshape(n, -1), gates.reshape(n, -1), E, cap)
    # (E, cap, d) -> exchange: rank r receives all ranks' slices for its
    # E/N experts -> (E/N, N*cap, d)
    buf = jax.lax.all_to_all(buf, CP_AXIS, split_axis=0, concat_axis=1,
                             tiled=True)
    y = expert_ffn(buf, wi, wg, wo, kind)
    y = jax.lax.all_to_all(y, CP_AXIS, split_axis=1, concat_axis=0,
                           tiled=True)                     # (E, cap, d)
    out = combine_local(y, slot, tok_s, gat_s, keep, n)
    return out.reshape(b, t, d)


def _selective_scan_island(dt, A, Bm, Cm, xf, reset):
    """Fused chunkwise selective scan with CP rank hand-off.

    Pass 1 computes each rank's (decay, state) summary; an all-gather +
    associative prefix combine yields each rank's initial state; pass 2
    produces y with chunk-local memory (models/context.py).
    """
    from repro.models.context import local_selective_scan

    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)

    A_rank, S_rank = local_selective_scan(dt, A, Bm, Cm, xf, reset,
                                          summary_only=True)
    gA = jax.lax.all_gather(A_rank, CP_AXIS, axis=0)
    gS = jax.lax.all_gather(S_rank, CP_AXIS, axis=0)

    def comb(carry, j):
        A_c, S_c = carry
        take = j < me
        A_n = jnp.where(take, gA[j] * A_c, A_c)
        S_n = jnp.where(take, gS[j] + gA[j] * S_c, S_c)
        return (A_n, S_n), None

    init = (jnp.ones_like(A_rank), jnp.zeros_like(S_rank))
    (_, S0), _ = jax.lax.scan(comb, init, jnp.arange(N))
    return local_selective_scan(dt, A, Bm, Cm, xf, reset, init_state=S0)


def _ssm_island(a, x):
    """Cross-rank recurrence: local scan + associative prefix combine."""
    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)

    h_loc = local_ssm_scan(a, x)
    # decay track kept at a's (possibly broadcast/singleton) shape
    cum_a = local_ssm_scan(a, jnp.zeros_like(a), init=jnp.ones_like(a[:, 0]))

    A_tot = cum_a[:, -1]                        # (b, ...)
    h_last = h_loc[:, -1]
    gA = jax.lax.all_gather(A_tot, CP_AXIS, axis=0)     # (N, b, ...)
    gH = jax.lax.all_gather(h_last, CP_AXIS, axis=0)

    def comb(carry, j):
        A_c, H_c = carry
        take = j < me
        A_n = jnp.where(take, gA[j] * A_c, A_c)
        H_n = jnp.where(take, gH[j] + gA[j] * H_c, H_c)
        return (A_n, H_n), None

    init = (jnp.ones_like(A_tot), jnp.zeros_like(h_last))
    (_, H_prev), _ = jax.lax.scan(comb, init, jnp.arange(N))
    return h_loc + cum_a * jnp.expand_dims(H_prev, 1)


# ===================================================================== #
# context factory
# ===================================================================== #
def make_cp_context(
    mesh,
    plan_arrays: dict[str, Any],
    *,
    strategy: str = "flashcp",
    impl: str = "xla",
    batch_axes=("data",),
    head_dim: int,
    q_chunk: int = 512,
    interpret: bool = False,
    tables: tuple | None = None,
    block_q: int = 128,
    block_k: int = 128,
    kv_comm_dtype: str = "native",
) -> ExecContext:
    """Build the ExecContext driving a CP training/prefill step.

    ``plan_arrays`` are the (jnp) outputs of
    :func:`repro.core.plan_exec.encode_plan_batch`, in global (B, ·) view.
    """
    doc = plan_arrays["doc"]
    pos = plan_arrays["pos"]
    b = tuple(batch_axes) if isinstance(batch_axes, (tuple, list)) \
        else (batch_axes,)
    B = b[0] if len(b) == 1 else b      # P dim entry: name or tuple of names
    scale = head_dim ** -0.5

    qkv_spec = P(B, None, CP_AXIS, None)
    tok_spec = P(B, CP_AXIS)

    if strategy in ("flashcp", "contiguous"):
        island = functools.partial(_flashcp_island, impl=impl,
                                   q_chunk=q_chunk, interpret=interpret,
                                   kv_comm_dtype=kv_comm_dtype)
        in_specs = [qkv_spec, qkv_spec, qkv_spec, tok_spec, tok_spec,
                    P(B, CP_AXIS, None), P(B, None), P(B, None)]
        args = (plan_arrays["send_idx"], plan_arrays["gath_doc"],
                plan_arrays["gath_pos"])
        if impl == "pallas":
            assert tables is not None

            def island(q, k, v, d_, p_, si, gd, gp, *tabs):  # noqa: F811
                return _flashcp_island(q, k, v, d_, p_, si, gd, gp,
                                       impl=impl, q_chunk=q_chunk,
                                       interpret=interpret, tables=tabs,
                                       block_q=block_q, block_k=block_k,
                                       kv_comm_dtype=kv_comm_dtype)

            in_specs = in_specs + [P(B, CP_AXIS, None, None),
                                   P(B, CP_AXIS, None),
                                   P(B, CP_AXIS, None, None),
                                   P(B, CP_AXIS, None)]
            args = args + tuple(tables)

        def attn(q, k, v):
            f = shard_map(island, mesh=mesh, in_specs=tuple(in_specs),
                              out_specs=qkv_spec, check_vma=False)
            return f(q, k, v, doc, pos, *args)

    elif strategy in ("allgather", "llama3", "per_doc"):
        island = functools.partial(_allgather_island, impl=impl,
                                   q_chunk=q_chunk, interpret=interpret)

        def attn(q, k, v):
            f = shard_map(
                island, mesh=mesh,
                in_specs=(qkv_spec, qkv_spec, qkv_spec, tok_spec, tok_spec),
                out_specs=qkv_spec, check_vma=False)
            return f(q, k, v, doc, pos)

    elif strategy in ("ring", "ring_zigzag"):
        island = functools.partial(_ring_island, q_chunk=q_chunk, scale=scale)

        def attn(q, k, v):
            f = shard_map(
                island, mesh=mesh,
                in_specs=(qkv_spec, qkv_spec, qkv_spec, tok_spec, tok_spec),
                out_specs=qkv_spec, check_vma=False)
            return f(q, k, v, doc, pos)

    else:
        raise ValueError(f"unknown CP strategy {strategy!r}")

    def ssm_scan(a, x):
        a_spec = P(B, CP_AXIS, *([None] * (a.ndim - 2)))
        x_spec = P(B, CP_AXIS, *([None] * (x.ndim - 2)))
        f = shard_map(_ssm_island, mesh=mesh,
                          in_specs=(a_spec, x_spec), out_specs=x_spec,
                          check_vma=False)
        return f(a, x)

    def selective_scan(dt, A, Bm, Cm, xf, reset):
        tok = P(B, CP_AXIS)
        tok3 = P(B, CP_AXIS, None)
        f = shard_map(
            _selective_scan_island, mesh=mesh,
            in_specs=(tok3, P(None, None), tok3, tok3, tok3, tok),
            out_specs=tok3, check_vma=False)
        return f(dt, A, Bm, Cm, xf, reset)

    def ep_dispatch(x, topi, gates, params, *, kind, capacity_factor):
        tok3 = P(B, CP_AXIS, None)
        expert = P("model", None, None)
        island = functools.partial(_moe_island, kind=kind,
                                   capacity_factor=capacity_factor,
                                   top_k=topi.shape[-1])
        wg = params.get("wg")
        if wg is None:
            wg = params["wi"]      # unused by gelu path; keeps arity static
        f = shard_map(
            island, mesh=mesh,
            in_specs=(tok3, tok3, tok3, expert, expert, expert),
            out_specs=tok3, check_vma=False)
        return f(x, topi, gates, params["wi"], wg, params["wo"])

    from jax.sharding import NamedSharding

    return ExecContext(doc=doc, pos=pos, attn=attn, ssm_scan=ssm_scan,
                       selective_scan=selective_scan,
                       act_sharding=NamedSharding(mesh, P(B, CP_AXIS, None)),
                       extras={"ep_dispatch": ep_dispatch})
