"""Device-side context parallelism: shard_map islands over the CP axis.

Every strategy now runs on one **partial-attention + online-LSE merge
substrate**: attention against any KV subset yields a merge-ready partial
``(o, m, l)`` (unnormalized accumulator, row max, row sum — or the
equivalent normalized ``(o, lse, 1)`` form the Pallas kernel emits), and
partials merge by the usual flash rescaling in any order.  Communication
strategies differ only in *which* KV subsets exist and how they move:

* ``flashcp`` / ``contiguous`` — **sharding-aware communication** (§3.2):
  only the compacted non-last-shard KV buffer (Eq. 5 volume) moves.
  ``overlap="chunked"`` (default) moves it in N-1 ``ppermute`` ring hops:
  local-KV attention runs concurrently with hop 0, and each arriving
  buffer attends while the next hop is in flight — the XLA latency-hiding
  scheduler overlaps the whole exchange with compute.  ``overlap="none"``
  keeps the original single blocking all-gather island (parity baseline).
  Backward is the JAX transpose either way — reduce-scatter (monolithic)
  or the reversed ppermute chain (chunked) of dKV at the same reduced
  volume (the paper's 4x factor).
* ``allgather`` — full-KV exchange (Eq. 4): Llama3 CP and Per-Doc CP;
  the same ``overlap`` switch applies with the full local KV as the
  hop payload.
* ``ring`` — Ring-Attention (Zigzag): N-1 hops of full local KV.
  ``overlap="chunked"`` is the substrate engine (Pallas-capable);
  ``overlap="none"`` selects the frozen pure-XLA seed loop.

Any strategy runs the Pallas block-sparse kernel per subset when
``impl="pallas"`` and per-rank visit tables are threaded in (the planner
emits them — :func:`repro.planner.encode.emit_visit_tables`; the data
pipeline forwards them as ``tab_*`` plan arrays).  ``grid`` picks the
kernel schedule: ``"flat"`` walks the flattened work-queue tables (one
grid step per actual visit), ``"rect"`` the padded rectangular layout
(parity baseline); the table key families differ accordingly
(``*_{kv,q}_{idx,nvis}`` vs ``*_{fq,rq}_{row,col,flags}``).

A self-ownership subtlety of the compact buffer: the monolithic all-gather
includes this rank's own contribution, which is *also* present as local
KV.  The island marks its own gathered segment invisible (doc id -2) so no
KV pair is double-counted.  The chunked exchange never attends its own
buffer (N-1 hops visit exactly the other ranks), so no masking is needed.

The SSM island implements cross-rank recurrence for Mamba/xLSTM: local
chunked scans + an all-gather of per-rank (decay, state) summaries with an
associative prefix combine — O(state) communication, no serialization
across ranks.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import axis_size, shard_map
from repro.table_layout import GRID_TABLE_HALF, table_keys
from jax.sharding import PartitionSpec as P

from repro.models.context import ExecContext, local_ssm_scan

__all__ = ["make_cp_context", "resolve_overlap", "CP_AXIS",
           "merge_partials", "finalize_partial", "merge_partials_axis"]

CP_AXIS = "model"
NEG = -1e30


def resolve_overlap(strategy: str, impl: str, overlap: str) -> str:
    """Effective overlap mode for (strategy, impl).

    Ring has no monolithic Pallas form — its only kernel-capable engine
    is the chunked substrate — so ring+pallas upgrades ``"none"`` to
    ``"chunked"``.  The single source of truth for table emission
    (data/pipeline.py), AOT input specs (launch/steps.py), and the
    context dispatch below.
    """
    if overlap not in ("none", "chunked"):
        raise ValueError(f"unknown overlap mode {overlap!r}")
    if strategy in ("ring", "ring_zigzag") and impl == "pallas":
        return "chunked"
    return overlap


# ===================================================================== #
# helpers
# ===================================================================== #
def _take_tokens(x, idx):
    """x (b, H, T, D); idx (b, S) with -1 padding -> (b, H, S, D), zeroed
    at padding."""
    safe = jnp.maximum(idx, 0)[:, None, :, None]
    out = jnp.take_along_axis(x, safe, axis=2)
    return out * (idx >= 0)[:, None, :, None].astype(x.dtype)


# ===================================================================== #
# partial-attention + online-LSE merge substrate
# ===================================================================== #
def _merge_step(acc, part):
    """Online-LSE merge of two partials; associative and (to fp rounding)
    commutative — hop order never changes the result beyond tolerance."""
    ao, am, al = acc
    o, m, l = part
    m_new = jnp.maximum(am, m)
    c1 = jnp.exp(am - m_new)
    c2 = jnp.exp(m - m_new)
    return (ao * c1[..., None] + o * c2[..., None], m_new, al * c1 + l * c2)


def merge_partials(parts):
    """Fold a sequence of (o, m, l) partials into one (tests/benchmarks)."""
    acc = None
    for p in parts:
        acc = p if acc is None else _merge_step(acc, p)
    return acc


def merge_partials_axis(part, axis_name):
    """Collective form of :func:`merge_partials`: fold one (o, m, l)
    partial per rank across a mesh axis (inside shard_map/pmap).  The
    global row max moves via pmax; every rank rescales to it and psums
    the accumulator and the sum — the distributed flash-decode LSE merge
    (serving: each rank attends its cache shard, then merges here)."""
    o, m, l = part
    m_g = jax.lax.pmax(m, axis_name)
    c = jnp.exp(m - m_g)
    o_g = jax.lax.psum(o * c[..., None], axis_name)
    l_g = jax.lax.psum(l * c, axis_name)
    return o_g, m_g, l_g


def finalize_partial(part, dtype):
    """Normalize a merged partial into the attention output (0 where no
    KV was visible)."""
    o, _, l = part
    out = jnp.where(l[..., None] > 0, o / jnp.maximum(l[..., None], 1e-30),
                    0.0)
    return out.astype(dtype)


def _partial_masked_attention(q, k, v, q_doc, q_pos, kv_doc, kv_pos, *,
                              impl, scale, q_chunk, interpret, tables=None,
                              block_q=128, block_k=128, grid="rect"):
    """Merge-ready partial against one KV subset, on either kernel.

    The Pallas kernel emits the normalized ``(o, lse)`` form, re-expressed
    as the triple ``(o, m=lse, l=1)``; the two forms are interchangeable
    under :func:`_merge_step` (``o * exp(lse - M)`` recovers the
    unnormalized accumulator either way).  ``lse`` is clamped to the
    finite NEG stand-in so empty rows contribute weight exp(NEG - M) = 0
    and their cotangent is dropped by the clamp's gradient.
    """
    from repro.kernels import ops as kops

    if impl == "pallas":
        assert tables is not None, "pallas CP attention needs host tables"
        o, lse = kops.doc_flash_attention(
            q, k, v, q_doc, q_pos, kv_doc, kv_pos, tables, scale=scale,
            interpret=interpret, block_q=block_q, block_k=block_k,
            grid=grid, partial=True)
        m = jnp.maximum(lse, NEG)
        return o.astype(jnp.float32), m, jnp.ones_like(m)
    return kops.doc_attention_xla(q, k, v, q_doc, q_pos, kv_doc, kv_pos,
                                  scale=scale, q_chunk=q_chunk, partial=True)


def _masked_attention(q, k, v, q_doc, q_pos, kv_doc, kv_pos, *, impl,
                      q_chunk, interpret, tables=None, block_q=128,
                      block_k=128, grid="rect"):
    from repro.kernels import ops as kops

    if impl == "pallas":
        assert tables is not None, "pallas CP attention needs host tables"
        return kops.doc_flash_attention(q, k, v, q_doc, q_pos, kv_doc,
                                        kv_pos, tables, interpret=interpret,
                                        block_q=block_q, block_k=block_k,
                                        grid=grid)
    return kops.doc_attention_xla(q, k, v, q_doc, q_pos, kv_doc, kv_pos,
                                  q_chunk=q_chunk)


# ===================================================================== #
# islands
# ===================================================================== #
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _quantized_gather(x, axis_name):
    """int8 all-gather with per-(batch, head, token) scales — beyond-paper
    comm compression of the Eq. 5 KV exchange (EXPERIMENTS.md §Perf #6).

    Straight-through backward: ``round`` has zero gradient, so the VJP is
    defined explicitly as the transpose of a plain gather — a full-precision
    reduce-scatter of dKV (gradients stay exact; only the forward KV wire
    is quantized)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                  127).astype(jnp.int8)
    g8 = jax.lax.all_gather(q8, axis_name, axis=2, tiled=True)
    gs = jax.lax.all_gather(scale.astype(jnp.float32), axis_name, axis=2,
                            tiled=True)
    return (g8.astype(jnp.float32) * gs).astype(x.dtype)


def _quantized_gather_fwd(x, axis_name):
    return _quantized_gather(x, axis_name), None


def _quantized_gather_bwd(axis_name, _, g):
    return (jax.lax.psum_scatter(g, axis_name, scatter_dimension=2,
                                 tiled=True),)


_quantized_gather.defvjp(_quantized_gather_fwd, _quantized_gather_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _quantized_ppermute(x, axis_name, perm):
    """int8 ppermute hop with per-(batch, head, token) scales — the
    chunked-exchange counterpart of :func:`_quantized_gather`.

    Straight-through backward: the hop's transpose is the inverse
    ppermute of the full-precision cotangent, so gradients stay exact and
    only the forward KV wire is quantized.  Each hop requantizes the
    arriving (already dequantized) buffer, so per-hop error accumulates
    over the ring — bounded by hops x one quantization step.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                  127).astype(jnp.int8)
    g8 = jax.lax.ppermute(q8, axis_name, perm)
    gs = jax.lax.ppermute(scale.astype(jnp.float32), axis_name, perm)
    return (g8.astype(jnp.float32) * gs).astype(x.dtype)


def _quantized_ppermute_fwd(x, axis_name, perm):
    return _quantized_ppermute(x, axis_name, perm), None


def _quantized_ppermute_bwd(axis_name, perm, _, g):
    inv = tuple((d, s) for (s, d) in perm)
    return (jax.lax.ppermute(g, axis_name, inv),)


_quantized_ppermute.defvjp(_quantized_ppermute_fwd, _quantized_ppermute_bwd)


def _wire_permute(x, perm, kv_comm_dtype):
    if kv_comm_dtype == "int8":
        return _quantized_ppermute(x, CP_AXIS, perm)
    return jax.lax.ppermute(x, CP_AXIS, perm)


# ===================================================================== #
# chunked-exchange engine: attend arriving KV while the next hop flies
# ===================================================================== #
def _run_hops(init_part, payload, n_hops, attend, hop_xs=None,
              kv_comm_dtype="native"):
    """Ring-rotate ``payload = (kc, vc, dc, pc)`` for ``n_hops`` hops,
    merging ``attend(kc, vc, dc, pc, xs)`` partials onto ``init_part``.

    Transfer/compute pipelining: the payload is launched to the neighbor
    *before* any remote attention (that first hop flies while the caller's
    local-KV partial computes), and each scan iteration forwards the
    arrived buffer in the same breath as attending it — the forward
    depends only on the buffer, never on the attention, so the XLA
    latency-hiding scheduler keeps hop h+1 in flight under hop h's
    compute.  The final hop is attended outside the scan and not
    forwarded, so total wire volume is exactly ``n_hops`` buffer hops —
    the same bytes as the monolithic all-gather, pipelined.  The scan
    transpose reverses the ppermute chain, routing each hop's dKV back to
    the owning rank at the same wire volume as the forward exchange.
    """
    if n_hops <= 0:
        return init_part
    N = axis_size(CP_AXIS)
    perm = tuple((i, (i + 1) % N) for i in range(N))

    def fwd(kc, vc, dc, pc):
        return (_wire_permute(kc, perm, kv_comm_dtype),
                _wire_permute(vc, perm, kv_comm_dtype),
                jax.lax.ppermute(dc, CP_AXIS, perm),
                jax.lax.ppermute(pc, CP_AXIS, perm))

    payload = fwd(*payload)       # hop 1 in flight under the local partial

    def step(carry, xs):
        kc, vc, dc, pc, acc, m, l = carry
        nxt = fwd(kc, vc, dc, pc)
        part = attend(kc, vc, dc, pc, xs)
        acc, m, l = _merge_step((acc, m, l), part)
        return (*nxt, acc, m, l), None

    xs_scan = xs_last = None
    if hop_xs is not None:
        xs_scan = tuple(a[:n_hops - 1] for a in hop_xs)
        xs_last = tuple(a[n_hops - 1] for a in hop_xs)
    carry, _ = jax.lax.scan(step, (*payload, *init_part), xs_scan,
                            length=n_hops - 1)
    last = attend(*carry[:4], xs_last)
    return _merge_step(carry[4:], last)


def _unpack_rank_tables(tabs):
    """Strip the sharded-to-1 rank dim of per-rank table arrays."""
    if tabs is None:
        return None
    return tuple(t[:, 0] for t in tabs)


def _hop_xs_of(hop_tabs):
    """(b, H, ...) hop tables -> scan xs with the hop axis leading."""
    if hop_tabs is None:
        return None
    return tuple(jnp.moveaxis(t, 1, 0) for t in hop_tabs)


def _flashcp_island(q, k, v, doc, pos, send_idx, gath_doc, gath_pos,
                    *, impl, q_chunk, interpret, tables=None, block_q=128,
                    block_k=128, grid="rect", kv_comm_dtype="native"):
    b = q.shape[0]
    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)
    buf = send_idx.shape[-1]

    sidx = send_idx[:, 0]                       # (b, buf)
    ksel = _take_tokens(k, sidx)
    vsel = _take_tokens(v, sidx)
    if kv_comm_dtype == "int8":
        kg = _quantized_gather(ksel, CP_AXIS)
        vg = _quantized_gather(vsel, CP_AXIS)
    else:
        kg = jax.lax.all_gather(ksel, CP_AXIS, axis=2, tiled=True)
        vg = jax.lax.all_gather(vsel, CP_AXIS, axis=2, tiled=True)

    # hide my own gathered segment (those tokens are already local KV)
    seg = jnp.arange(N * buf, dtype=jnp.int32) // buf
    gdoc = jnp.where((seg == me)[None, :], -2, gath_doc)

    kv_k = jnp.concatenate([k, kg], axis=2)
    kv_v = jnp.concatenate([v, vg], axis=2)
    kv_doc = jnp.concatenate([doc, gdoc], axis=1)
    kv_pos = jnp.concatenate([pos, gath_pos], axis=1)

    tabs = None
    if tables is not None:
        tabs = tuple(t[:, 0] if t.ndim > 2 and t.shape[1] == 1 else t
                     for t in tables)
    return _masked_attention(q, kv_k, kv_v, doc, pos, kv_doc, kv_pos,
                             impl=impl, q_chunk=q_chunk, interpret=interpret,
                             tables=tabs, block_q=block_q, block_k=block_k,
                             grid=grid)


def _flashcp_island_chunked(q, k, v, doc, pos, send_idx, gath_doc, gath_pos,
                            *, impl, scale, q_chunk, interpret,
                            loc_tables=None, hop_tables=None, block_q=128,
                            block_k=128, grid="rect",
                            kv_comm_dtype="native"):
    """Overlapped sharding-aware exchange: the compacted Eq.-5 buffer
    moves in N-1 ppermute hops; each arriving buffer attends while the
    next hop is in flight, and local-KV attention overlaps hop 0.  After
    hop h a rank holds the buffer of rank (me - h) mod N, so the N-1 hops
    visit exactly the other ranks — the monolithic island's self-segment
    masking is unnecessary by construction."""
    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)
    buf = send_idx.shape[-1]

    sidx = send_idx[:, 0]                       # (b, buf)
    ksel = _take_tokens(k, sidx)
    vsel = _take_tokens(v, sidx)
    # this rank's slice of the (replicated) gathered-buffer metadata
    my_doc = jax.lax.dynamic_slice_in_dim(gath_doc, me * buf, buf, axis=1)
    my_pos = jax.lax.dynamic_slice_in_dim(gath_pos, me * buf, buf, axis=1)

    attend = functools.partial(
        _partial_masked_attention, impl=impl, scale=scale, q_chunk=q_chunk,
        interpret=interpret, block_q=block_q, block_k=block_k, grid=grid)
    init = attend(q, k, v, doc, pos, doc, pos,
                  tables=_unpack_rank_tables(loc_tables))

    def hop_attend(kc, vc, dc, pc, xs):
        return attend(q, kc, vc, doc, pos, dc, pc, tables=xs)

    part = _run_hops(init, (ksel, vsel, my_doc, my_pos), N - 1, hop_attend,
                     hop_xs=_hop_xs_of(_unpack_rank_tables(hop_tables)),
                     kv_comm_dtype=kv_comm_dtype)
    return finalize_partial(part, q.dtype)


def _allgather_island(q, k, v, doc, pos, *, impl, q_chunk, interpret,
                      tables=None, block_q=128, block_k=128, grid="rect",
                      kv_comm_dtype="native"):
    if kv_comm_dtype == "int8":
        kg = _quantized_gather(k, CP_AXIS)
        vg = _quantized_gather(v, CP_AXIS)
    else:
        kg = jax.lax.all_gather(k, CP_AXIS, axis=2, tiled=True)
        vg = jax.lax.all_gather(v, CP_AXIS, axis=2, tiled=True)
    gdoc = jax.lax.all_gather(doc, CP_AXIS, axis=1, tiled=True)
    gpos = jax.lax.all_gather(pos, CP_AXIS, axis=1, tiled=True)
    return _masked_attention(q, kg, vg, doc, pos, gdoc, gpos, impl=impl,
                             q_chunk=q_chunk, interpret=interpret,
                             tables=_unpack_rank_tables(tables),
                             block_q=block_q, block_k=block_k, grid=grid)


def _gather_island_chunked(q, k, v, doc, pos, *, impl, scale, q_chunk,
                           interpret, loc_tables=None, hop_tables=None,
                           block_q=128, block_k=128, grid="rect",
                           kv_comm_dtype="native"):
    """Overlapped full-KV exchange (allgather strategies, ring): the full
    local KV ring-rotates in N-1 hops on the merge substrate — identical
    results to the monolithic gather, with the wire pipelined behind
    per-hop attention."""
    attend = functools.partial(
        _partial_masked_attention, impl=impl, scale=scale, q_chunk=q_chunk,
        interpret=interpret, block_q=block_q, block_k=block_k, grid=grid)
    init = attend(q, k, v, doc, pos, doc, pos,
                  tables=_unpack_rank_tables(loc_tables))

    def hop_attend(kc, vc, dc, pc, xs):
        return attend(q, kc, vc, doc, pos, dc, pc, tables=xs)

    part = _run_hops(init, (k, v, doc, pos), axis_size(CP_AXIS) - 1,
                     hop_attend,
                     hop_xs=_hop_xs_of(_unpack_rank_tables(hop_tables)),
                     kv_comm_dtype=kv_comm_dtype)
    return finalize_partial(part, q.dtype)


def _ring_island(q, k, v, doc, pos, *, q_chunk, scale):
    """Seed Ring-Attention loop (pure XLA), kept as the ``overlap="none"``
    parity baseline; the chunked engine generalizes it with Pallas-kernel
    hops and int8 wire support."""
    b, Hq, T, D = q.shape
    N = axis_size(CP_AXIS)
    perm = [(i, (i + 1) % N) for i in range(N)]

    acc = jnp.zeros((b, Hq, T, D), jnp.float32)
    m = jnp.full((b, Hq, T), NEG, jnp.float32)
    l = jnp.zeros((b, Hq, T), jnp.float32)

    def step(carry, _):
        kc, vc, dc, pc, acc, m, l = carry
        part = _partial_masked_attention(
            q, kc, vc, doc, pos, dc, pc, impl="xla", scale=scale,
            q_chunk=q_chunk, interpret=False)
        acc, m, l = _merge_step((acc, m, l), part)
        kc = jax.lax.ppermute(kc, CP_AXIS, perm)
        vc = jax.lax.ppermute(vc, CP_AXIS, perm)
        dc = jax.lax.ppermute(dc, CP_AXIS, perm)
        pc = jax.lax.ppermute(pc, CP_AXIS, perm)
        return (kc, vc, dc, pc, acc, m, l), None

    (kc, vc, dc, pc, acc, m, l), _ = jax.lax.scan(
        step, (k, v, doc, pos, acc, m, l), None, length=N)
    return finalize_partial((acc, m, l), q.dtype)


def _moe_island(x, topi, gates, wi, wg, wo, *, kind, capacity_factor,
                top_k):
    """Expert-parallel dispatch: local capacity-clipped routing buffers
    exchanged with all-to-all over the ``model`` axis (experts are sharded
    over that axis), expert FFN on owned experts, all-to-all back, local
    weighted combine."""
    from repro.models.moe import (capacity, combine_local, dispatch_local,
                                  expert_ffn)

    b, t, d = x.shape
    N = axis_size(CP_AXIS)
    E_local = wi.shape[0]
    E = E_local * N
    n = b * t
    cap = capacity(n, E, top_k, capacity_factor)

    buf, slot, tok_s, gat_s, keep = dispatch_local(
        x.reshape(n, d), topi.reshape(n, -1), gates.reshape(n, -1), E, cap)
    # (E, cap, d) -> exchange: rank r receives all ranks' slices for its
    # E/N experts -> (E/N, N*cap, d)
    buf = jax.lax.all_to_all(buf, CP_AXIS, split_axis=0, concat_axis=1,
                             tiled=True)
    y = expert_ffn(buf, wi, wg, wo, kind)
    y = jax.lax.all_to_all(y, CP_AXIS, split_axis=1, concat_axis=0,
                           tiled=True)                     # (E, cap, d)
    out = combine_local(y, slot, tok_s, gat_s, keep, n)
    return out.reshape(b, t, d)


def _selective_scan_island(dt, A, Bm, Cm, xf, reset):
    """Fused chunkwise selective scan with CP rank hand-off.

    Pass 1 computes each rank's (decay, state) summary; an all-gather +
    associative prefix combine yields each rank's initial state; pass 2
    produces y with chunk-local memory (models/context.py).
    """
    from repro.models.context import local_selective_scan

    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)

    A_rank, S_rank = local_selective_scan(dt, A, Bm, Cm, xf, reset,
                                          summary_only=True)
    gA = jax.lax.all_gather(A_rank, CP_AXIS, axis=0)
    gS = jax.lax.all_gather(S_rank, CP_AXIS, axis=0)

    def comb(carry, j):
        A_c, S_c = carry
        take = j < me
        A_n = jnp.where(take, gA[j] * A_c, A_c)
        S_n = jnp.where(take, gS[j] + gA[j] * S_c, S_c)
        return (A_n, S_n), None

    init = (jnp.ones_like(A_rank), jnp.zeros_like(S_rank))
    (_, S0), _ = jax.lax.scan(comb, init, jnp.arange(N))
    return local_selective_scan(dt, A, Bm, Cm, xf, reset, init_state=S0)


def _ssm_island(a, x):
    """Cross-rank recurrence: local scan + associative prefix combine."""
    N = axis_size(CP_AXIS)
    me = jax.lax.axis_index(CP_AXIS)

    h_loc = local_ssm_scan(a, x)
    # decay track kept at a's (possibly broadcast/singleton) shape
    cum_a = local_ssm_scan(a, jnp.zeros_like(a), init=jnp.ones_like(a[:, 0]))

    A_tot = cum_a[:, -1]                        # (b, ...)
    h_last = h_loc[:, -1]
    gA = jax.lax.all_gather(A_tot, CP_AXIS, axis=0)     # (N, b, ...)
    gH = jax.lax.all_gather(h_last, CP_AXIS, axis=0)

    def comb(carry, j):
        A_c, H_c = carry
        take = j < me
        A_n = jnp.where(take, gA[j] * A_c, A_c)
        H_n = jnp.where(take, gH[j] + gA[j] * H_c, H_c)
        return (A_n, H_n), None

    init = (jnp.ones_like(A_tot), jnp.zeros_like(h_last))
    (_, H_prev), _ = jax.lax.scan(comb, init, jnp.arange(N))
    return h_loc + cum_a * jnp.expand_dims(H_prev, 1)


# ===================================================================== #
# context factory
# ===================================================================== #
MONO_TABLE_KEYS = table_keys("tab_", "rect")
LOC_TABLE_KEYS = table_keys("tab_loc_", "rect")
HOP_TABLE_KEYS = table_keys("tab_hop_", "rect")


def make_cp_context(
    mesh,
    plan_arrays: dict[str, Any],
    *,
    strategy: str = "flashcp",
    impl: str = "xla",
    batch_axes=("data",),
    head_dim: int,
    q_chunk: int = 512,
    overlap: str = "chunked",
    interpret: bool = False,
    tables: tuple | None = None,
    block_q: int = 128,
    block_k: int = 128,
    grid: str = "rect",
    kv_comm_dtype: str = "native",
) -> ExecContext:
    """Build the ExecContext driving a CP training/prefill step.

    ``plan_arrays`` are the (jnp) outputs of
    :func:`repro.planner.encode.encode_plan_batch`, in global (B, ·) view,
    optionally extended with per-rank Pallas visit tables (``tab_*`` keys,
    :func:`repro.planner.encode.emit_visit_tables`).

    ``overlap="chunked"`` (default) runs the overlapped chunked-KV
    exchange engine; ``overlap="none"`` the original monolithic islands.
    ``impl="pallas"`` requires visit tables matching ``grid``: the
    rectangular 4-tuple layout for ``grid="rect"`` (``tables=`` or
    ``tab_*`` plan arrays) or the flattened work-queue 6-tuple layout
    for ``grid="flat"`` (``tab_*{fq,rq}_*`` plan arrays); the chunked
    engine takes per-rank local + per-hop sets either way (``tab_loc_*``
    / ``tab_hop_*``).
    """
    overlap = resolve_overlap(strategy, impl, overlap)
    if grid not in ("rect", "flat"):
        raise ValueError(f"unknown kernel grid {grid!r}")
    doc = plan_arrays["doc"]
    pos = plan_arrays["pos"]
    b = tuple(batch_axes) if isinstance(batch_axes, (tuple, list)) \
        else (batch_axes,)
    B = b[0] if len(b) == 1 else b      # P dim entry: name or tuple of names
    scale = head_dim ** -0.5
    n_tab = 2 * GRID_TABLE_HALF[grid]   # arrays per table set

    qkv_spec = P(B, None, CP_AXIS, None)
    tok_spec = P(B, CP_AXIS)

    def _plan_tables(keys):
        if all(k in plan_arrays for k in keys):
            return tuple(plan_arrays[k] for k in keys)
        return None

    def _table_specs(arrs):
        return [P(B, CP_AXIS, *([None] * (a.ndim - 2))) for a in arrs]

    def _chunked_tables(what):
        if impl != "pallas":
            return ()
        loc = _plan_tables(table_keys("tab_loc_", grid))
        hop = _plan_tables(table_keys("tab_hop_", grid))
        if loc is None or hop is None:
            raise ValueError(
                f"pallas {what} with overlap='chunked' needs per-rank "
                f"local + per-hop grid={grid!r} visit tables "
                "(tab_loc_*/tab_hop_* plan arrays; see "
                "repro.planner.encode.emit_visit_tables)")
        return loc + hop

    def _mono_tables(what):
        if impl != "pallas":
            return ()
        mono = tables if tables is not None \
            else _plan_tables(table_keys("tab_", grid))
        if mono is None:
            raise ValueError(
                f"pallas {what} needs grid={grid!r} visit tables "
                "(tables= or tab_* plan arrays; see "
                "repro.planner.encode.emit_visit_tables)")
        return tuple(mono)

    if strategy in ("flashcp", "contiguous"):
        base_args = (plan_arrays["send_idx"], plan_arrays["gath_doc"],
                     plan_arrays["gath_pos"])
        base_specs = [qkv_spec, qkv_spec, qkv_spec, tok_spec, tok_spec,
                      P(B, CP_AXIS, None), P(B, None), P(B, None)]
        if overlap == "chunked":
            tabs = _chunked_tables("flashcp")

            def island(q, k, v, d_, p_, si, gd, gp, *tt):
                return _flashcp_island_chunked(
                    q, k, v, d_, p_, si, gd, gp, impl=impl, scale=scale,
                    q_chunk=q_chunk, interpret=interpret,
                    loc_tables=tt[:n_tab] or None,
                    hop_tables=tt[n_tab:] or None,
                    block_q=block_q, block_k=block_k, grid=grid,
                    kv_comm_dtype=kv_comm_dtype)
        else:
            tabs = _mono_tables("flashcp")

            def island(q, k, v, d_, p_, si, gd, gp, *tt):
                return _flashcp_island(
                    q, k, v, d_, p_, si, gd, gp, impl=impl, q_chunk=q_chunk,
                    interpret=interpret, tables=tt or None,
                    block_q=block_q, block_k=block_k, grid=grid,
                    kv_comm_dtype=kv_comm_dtype)

        in_specs = base_specs + _table_specs(tabs)
        args = base_args + tabs

        def attn(q, k, v):
            f = shard_map(island, mesh=mesh, in_specs=tuple(in_specs),
                          out_specs=qkv_spec, check_vma=False)
            return f(q, k, v, doc, pos, *args)

    elif strategy in ("allgather", "llama3", "per_doc", "ring",
                      "ring_zigzag"):
        is_ring = strategy in ("ring", "ring_zigzag")
        if overlap == "chunked":
            tabs = _chunked_tables(strategy)

            def island(q, k, v, d_, p_, *tt):
                return _gather_island_chunked(
                    q, k, v, d_, p_, impl=impl, scale=scale,
                    q_chunk=q_chunk, interpret=interpret,
                    loc_tables=tt[:n_tab] or None,
                    hop_tables=tt[n_tab:] or None,
                    block_q=block_q, block_k=block_k, grid=grid,
                    kv_comm_dtype=kv_comm_dtype)
        elif is_ring:
            tabs = ()
            island = functools.partial(_ring_island, q_chunk=q_chunk,
                                       scale=scale)
        else:
            tabs = _mono_tables(strategy)

            def island(q, k, v, d_, p_, *tt):
                return _allgather_island(
                    q, k, v, d_, p_, impl=impl, q_chunk=q_chunk,
                    interpret=interpret, tables=tt or None,
                    block_q=block_q, block_k=block_k, grid=grid,
                    kv_comm_dtype=kv_comm_dtype)

        in_specs = [qkv_spec, qkv_spec, qkv_spec, tok_spec, tok_spec] \
            + _table_specs(tabs)

        def attn(q, k, v):
            f = shard_map(island, mesh=mesh, in_specs=tuple(in_specs),
                          out_specs=qkv_spec, check_vma=False)
            return f(q, k, v, doc, pos, *tabs)

    else:
        raise ValueError(f"unknown CP strategy {strategy!r}")

    def ssm_scan(a, x):
        a_spec = P(B, CP_AXIS, *([None] * (a.ndim - 2)))
        x_spec = P(B, CP_AXIS, *([None] * (x.ndim - 2)))
        f = shard_map(_ssm_island, mesh=mesh,
                          in_specs=(a_spec, x_spec), out_specs=x_spec,
                          check_vma=False)
        return f(a, x)

    def selective_scan(dt, A, Bm, Cm, xf, reset):
        tok = P(B, CP_AXIS)
        tok3 = P(B, CP_AXIS, None)
        f = shard_map(
            _selective_scan_island, mesh=mesh,
            in_specs=(tok3, P(None, None), tok3, tok3, tok3, tok),
            out_specs=tok3, check_vma=False)
        return f(dt, A, Bm, Cm, xf, reset)

    def ep_dispatch(x, topi, gates, params, *, kind, capacity_factor):
        tok3 = P(B, CP_AXIS, None)
        expert = P("model", None, None)
        island = functools.partial(_moe_island, kind=kind,
                                   capacity_factor=capacity_factor,
                                   top_k=topi.shape[-1])
        wg = params.get("wg")
        if wg is None:
            wg = params["wi"]      # unused by gelu path; keeps arity static
        f = shard_map(
            island, mesh=mesh,
            in_specs=(tok3, tok3, tok3, expert, expert, expert),
            out_specs=tok3, check_vma=False)
        return f(x, topi, gates, params["wi"], wg, params["wo"])

    from jax.sharding import NamedSharding

    return ExecContext(doc=doc, pos=pos, attn=attn, ssm_scan=ssm_scan,
                       selective_scan=selective_scan,
                       act_sharding=NamedSharding(mesh, P(B, CP_AXIS, None)),
                       extras={"ep_dispatch": ep_dispatch})
