"""Workload & communication accounting for CP sharding plans (paper §3.1–3.2).

All byte formulas follow the paper exactly:

  Eq. 4 (static full-KV exchange, Llama3 CP / Per-Doc CP / Ring-Attn):
      bytes = 4 * (Σ d_i / N) * H * D * (N - 1) * dtype_bytes

  Eq. 5 (FlashCP sharding-aware exchange):
      bytes = 4 * (max_j Σ_{i∈Ŝ} x_ij s_i) * H * D * (N - 1) * dtype_bytes

The leading 4 covers K and V in both forward and backward.  ``H`` is the
number of **KV** heads (GQA communicates only KV heads — for MQA models such
as granite-34b this makes CP comm 48x smaller than a Q exchange would be) and
``D`` the head dimension.
"""

from __future__ import annotations

import numpy as np

from repro.planner.plan import ShardingPlan

__all__ = [
    "shard_workload",
    "causal_doc_workload",
    "comm_tokens_static",
    "comm_tokens_flashcp",
    "comm_bytes",
    "plan_comm_bytes",
    "comm_saving",
]


def shard_workload(prefix: int, length: int) -> float:
    """W_i = (2 p_i + s_i + 1) * s_i / 2."""
    return (2 * prefix + length + 1) * length / 2.0


def causal_doc_workload(doc_len: int) -> float:
    """Total attention workload of one whole document: (d+1) d / 2."""
    return shard_workload(0, doc_len)


def total_workload(doc_lens) -> float:
    return float(sum(causal_doc_workload(int(d)) for d in doc_lens))


def comm_tokens_static(context_len: int, num_workers: int) -> int:
    """Per-rank KV tokens moved by a full exchange (Eq. 4 inner term)."""
    return context_len // num_workers


def comm_tokens_flashcp(plan: ShardingPlan) -> int:
    """Eq. 5 inner term: max_j Σ_{i∈Ŝ} x_ij s_i."""
    return int(np.max(plan.nonlast_tokens_per_worker()))


def comm_bytes(
    comm_tokens: int,
    num_workers: int,
    kv_heads: int,
    head_dim: int,
    *,
    dtype_bytes: int = 2,
    fwd_and_bwd: bool = True,
) -> int:
    """Bytes on the critical path for the KV exchange (Eq. 4 / Eq. 5 outer)."""
    factor = 4 if fwd_and_bwd else 2  # K and V; x2 again for fwd+bwd
    return factor * comm_tokens * kv_heads * head_dim * (num_workers - 1) * dtype_bytes


def plan_comm_bytes(
    plan: ShardingPlan,
    kv_heads: int,
    head_dim: int,
    *,
    dtype_bytes: int = 2,
    fwd_and_bwd: bool = True,
) -> int:
    """Critical-path KV-exchange bytes for a plan, honouring its comm style."""
    return comm_bytes(
        plan.comm_tokens(),
        plan.num_workers,
        kv_heads,
        head_dim,
        dtype_bytes=dtype_bytes,
        fwd_and_bwd=fwd_and_bwd,
    )


def comm_saving(plan: ShardingPlan) -> float:
    """Fraction of Eq. 4 traffic eliminated by sharding-aware comm (§4.3).

    The paper's "communication saving" metric: 1 - Eq.5 / Eq.4.
    """
    static = comm_tokens_static(plan.context_len, plan.num_workers)
    if static == 0:
        return 0.0
    return 1.0 - plan.comm_tokens() / static
