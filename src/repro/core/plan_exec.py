"""Plan execution encoding: ShardingPlan -> static-shaped device arrays.

XLA programs need static shapes, but FlashCP's plan is data-dependent.  The
split of labor (DESIGN.md §4):

* the planner output is encoded **per packed sequence** as a token
  permutation plus fixed-size metadata arrays;
* dynamic quantities (the Eq. 5 send-buffer size, the Pallas visit-table
  width) are **bucketed** to powers of two, so at most ``log2`` distinct
  executables exist and the compile cache absorbs them.

Plan-order layout: worker j's tokens occupy the contiguous slice
``[j*T_loc, (j+1)*T_loc)`` of every (B, C_pad) array.  Under pjit with the
sequence axis sharded over the ``model`` mesh axis, that slice *is* worker
j's local shard — host permutation implements FlashCP's token distribution
with zero device-side data movement.

Send-buffer semantics (sharding-aware communication, §3.2): worker j
contributes the KV of its *non-last* document shards, compacted (no
per-document padding — the paper's "single continuous communication
buffer"), padded to the bucket ``buf_len``; the device all-gathers these
buffers so every worker can serve queries whose prefix lives remotely.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .plan import Shard, ShardingPlan

__all__ = ["PlanEncoding", "encode_plan", "encode_plan_batch",
           "pick_buffer_bucket", "trivial_plan"]


def _next_pow2(x: int, floor: int = 128) -> int:
    v = floor
    while v < x:
        v *= 2
    return v


def pick_buffer_bucket(comm_tokens: int, t_loc: int, floor: int = 128) -> int:
    """Static Eq.5 buffer size: pow2 bucket, at most the full local KV."""
    return min(_next_pow2(max(comm_tokens, 1), floor), _next_pow2(t_loc, floor))


@dataclasses.dataclass
class PlanEncoding:
    """Device-facing encoding of one packed sequence's sharding plan."""

    perm: np.ndarray        # (C_pad,) plan-order -> packed position (-1 pad)
    doc: np.ndarray         # (C_pad,) int32 doc id per plan-order token
    pos: np.ndarray         # (C_pad,) int32 intra-doc position
    send_idx: np.ndarray    # (N, buf_len) int32 local indices, -1 pad
    gath_doc: np.ndarray    # (N * buf_len,) int32, -1 pad
    gath_pos: np.ndarray    # (N * buf_len,) int32
    t_loc: int              # tokens per worker (C_pad // N)
    buf_len: int            # Eq. 5 bucket
    comm_tokens: int        # actual max_j non-last tokens (pre-bucket)
    imbalance: float


def trivial_plan(context_len: int) -> ShardingPlan:
    """Single-worker plan (smoke tests / local mode)."""
    return ShardingPlan(
        doc_lens=np.asarray([context_len], dtype=np.int64),
        shards=[Shard(0, 0, context_len, 0)],
        num_workers=1, comm_style="flashcp")


def encode_plan(
    plan: ShardingPlan,
    *,
    buf_len: int | None = None,
    t_loc: int | None = None,
    align: int = 1,
) -> PlanEncoding:
    N = plan.num_workers
    doc_starts = np.concatenate([[0], np.cumsum(plan.doc_lens)])[:-1]

    per_worker: list[list[Shard]] = [[] for _ in range(N)]
    for s in plan.shards:
        per_worker[s.worker].append(s)
    for j in range(N):
        per_worker[j].sort(key=lambda s: (s.doc_id, s.start))

    tokens_per_worker = [sum(s.length for s in ws) for ws in per_worker]
    need_t = max(tokens_per_worker)
    if t_loc is None:
        t_loc = need_t
        if align > 1:
            t_loc = ((t_loc + align - 1) // align) * align
    assert t_loc >= need_t, (t_loc, need_t)

    C_pad = N * t_loc
    perm = np.full(C_pad, -1, np.int64)
    doc = np.full(C_pad, -1, np.int32)
    pos = np.zeros(C_pad, np.int32)

    send_lists: list[np.ndarray] = []
    for j, ws in enumerate(per_worker):
        cursor = j * t_loc
        send_local: list[np.ndarray] = []
        for s in ws:
            rng = np.arange(s.start, s.end)
            perm[cursor: cursor + s.length] = doc_starts[s.doc_id] + rng
            doc[cursor: cursor + s.length] = s.doc_id
            pos[cursor: cursor + s.length] = rng
            if not s.is_last(int(plan.doc_lens[s.doc_id])):
                base = cursor - j * t_loc
                send_local.append(np.arange(base, base + s.length))
            cursor += s.length
        send_lists.append(
            np.concatenate(send_local) if send_local
            else np.zeros(0, np.int64))

    max_send = max((len(s) for s in send_lists), default=0)
    if buf_len is None:
        buf_len = pick_buffer_bucket(max_send, t_loc)
    assert buf_len >= max_send, (
        f"Eq.5 bucket {buf_len} < required send volume {max_send}")

    send_idx = np.full((N, buf_len), -1, np.int32)
    gath_doc = np.full(N * buf_len, -1, np.int32)
    gath_pos = np.zeros(N * buf_len, np.int32)
    for j, sl in enumerate(send_lists):
        send_idx[j, : len(sl)] = sl
        gath_doc[j * buf_len: j * buf_len + len(sl)] = doc[j * t_loc + sl]
        gath_pos[j * buf_len: j * buf_len + len(sl)] = pos[j * t_loc + sl]

    return PlanEncoding(
        perm=perm, doc=doc, pos=pos, send_idx=send_idx,
        gath_doc=gath_doc, gath_pos=gath_pos, t_loc=t_loc, buf_len=buf_len,
        comm_tokens=max_send, imbalance=plan.imbalance_ratio())


def encode_plan_batch(
    plans: list[ShardingPlan],
    *,
    buf_len: int | None = None,
    align: int = 1,
) -> tuple[dict[str, np.ndarray], list[PlanEncoding]]:
    """Encode a batch of per-sample plans with a common bucket.

    Returns (stacked arrays dict, per-sample encodings).  All samples share
    ``t_loc`` (max over batch, aligned) and ``buf_len`` (bucketed max).
    """
    N = plans[0].num_workers
    assert all(p.num_workers == N for p in plans)

    pre = [encode_plan(p, buf_len=None, align=align) for p in plans]
    t_loc = max(e.t_loc for e in pre)
    if buf_len is None:
        buf_len = max(e.buf_len for e in pre)
    encs = [encode_plan(p, buf_len=buf_len, t_loc=t_loc) for p in plans]

    stack = {
        "perm": np.stack([e.perm for e in encs]),
        "doc": np.stack([e.doc for e in encs]).astype(np.int32),
        "pos": np.stack([e.pos for e in encs]).astype(np.int32),
        "send_idx": np.stack([e.send_idx for e in encs]).astype(np.int32),
        "gath_doc": np.stack([e.gath_doc for e in encs]).astype(np.int32),
        "gath_pos": np.stack([e.gath_pos for e in encs]).astype(np.int32),
    }
    return stack, encs
