"""Legacy import path — the plan encoder lives in
:mod:`repro.planner.encode` (vectorized)."""

import warnings

warnings.warn(
    "repro.core.plan_exec is deprecated; import from repro.planner.encode instead",
    DeprecationWarning, stacklevel=2)

from repro.planner.encode import (PlanEncoding, encode_plan,  # noqa: F401
                                  encode_plan_batch, pick_buffer_bucket,
                                  plan_shape_hints, trivial_plan)

__all__ = ["PlanEncoding", "encode_plan", "encode_plan_batch",
           "pick_buffer_bucket", "trivial_plan"]
