"""Legacy import path — the plan data structures live in
:mod:`repro.planner.plan` (vectorized ShardArrays core)."""

import warnings

warnings.warn(
    "repro.core.plan is deprecated; import from repro.planner.plan instead",
    DeprecationWarning, stacklevel=2)

from repro.planner.plan import (Shard, ShardArrays, ShardingPlan,  # noqa: F401
                                make_whole_doc_plan,
                                merge_adjacent_shards,
                                shard_workload_array, validate_plan)

__all__ = [
    "Shard",
    "ShardArrays",
    "ShardingPlan",
    "make_whole_doc_plan",
    "validate_plan",
    "merge_adjacent_shards",
]
