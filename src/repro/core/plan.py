"""Sharding-plan data structures for FlashCP context parallelism.

Terminology follows the paper (§3.1):

* A packed input sequence of context length ``C`` contains ``n`` documents
  ``D = [d_1 .. d_n]`` (lengths).
* Documents are partitioned into ``m`` shards ``S = [s_1 .. s_m]``; shard
  ``i`` has a *prefix length* ``p_i`` — the number of tokens of the same
  document preceding its start.
* Each shard is assigned to exactly one CP worker (Eq. 1); every worker holds
  exactly ``C / N`` tokens (Eq. 2, the equal-token constraint).
* A shard is a **last shard** iff it contains the final token of its
  document.  Only *non-last* shards ever need their KV communicated (§3.2):
  some later shard of the same document (living on another worker) must
  attend to them.  Whole documents kept on one worker are last shards by
  definition and are never communicated.

Everything in this module is host-side ``numpy`` / pure Python; the
device-facing encoding lives in :mod:`repro.core.plan_exec`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Shard",
    "ShardingPlan",
    "make_whole_doc_plan",
    "validate_plan",
]


@dataclasses.dataclass(frozen=True)
class Shard:
    """A contiguous slice of one document, assigned to one CP worker."""

    doc_id: int
    start: int      # offset inside the document == prefix length p_i
    length: int     # s_i, in tokens
    worker: int

    @property
    def end(self) -> int:
        return self.start + self.length

    def is_last(self, doc_len: int) -> bool:
        return self.end == doc_len

    def workload(self) -> float:
        """Attention workload W_i = (2 p_i + s_i + 1) * s_i / 2 (paper §3.1).

        This is the number of (query, key) pairs evaluated by causal
        attention for this shard, counting its prefix context.
        """
        return (2 * self.start + self.length + 1) * self.length / 2.0


@dataclasses.dataclass
class ShardingPlan:
    """A complete sharding + distribution plan for one packed sequence."""

    doc_lens: np.ndarray          # (n,) int64 document lengths
    shards: list[Shard]           # all shards, all workers
    num_workers: int
    # how KV is exchanged at execution time; informs cost models and the
    # device-side executor.  "flashcp" = sharding-aware compact all-gather
    # (Eq. 5); "allgather" = full-KV all-gather (Eq. 4, Llama3/Per-Doc CP);
    # "ring" = P2P ring exchange of full KV (Ring-Attn).
    comm_style: str = "flashcp"

    # ------------------------------------------------------------------ #
    # basic derived quantities
    # ------------------------------------------------------------------ #
    @property
    def context_len(self) -> int:
        return int(np.sum(self.doc_lens))

    @property
    def num_docs(self) -> int:
        return len(self.doc_lens)

    def shards_of_worker(self, j: int) -> list[Shard]:
        return [s for s in self.shards if s.worker == j]

    def tokens_per_worker(self) -> np.ndarray:
        t = np.zeros(self.num_workers, dtype=np.int64)
        for s in self.shards:
            t[s.worker] += s.length
        return t

    def workload_per_worker(self) -> np.ndarray:
        w = np.zeros(self.num_workers, dtype=np.float64)
        for s in self.shards:
            w[s.worker] += s.workload()
        return w

    def imbalance_ratio(self) -> float:
        """max_workload / avg_workload across CP workers (paper §4.3)."""
        w = self.workload_per_worker()
        avg = float(np.mean(w))
        if avg == 0.0:
            return 1.0
        return float(np.max(w)) / avg

    # ------------------------------------------------------------------ #
    # communication (token counts; multiply by 4*H*D*(N-1) for bytes —
    # see repro.core.workload)
    # ------------------------------------------------------------------ #
    def nonlast_tokens_per_worker(self) -> np.ndarray:
        """Σ_{i∈Ŝ} x_ij s_i for each worker j — the Eq. 5 inner term."""
        t = np.zeros(self.num_workers, dtype=np.int64)
        for s in self.shards:
            if not s.is_last(int(self.doc_lens[s.doc_id])):
                t[s.worker] += s.length
        return t

    def comm_tokens(self) -> int:
        """Tokens each rank contributes to the KV exchange on the critical
        path.  For the sharding-aware scheme this is Eq. 5's max-term; for
        static schemes it is the full local KV, C / N (Eq. 4)."""
        if self.comm_style == "flashcp":
            return int(np.max(self.nonlast_tokens_per_worker()))
        return self.context_len // self.num_workers

    # ------------------------------------------------------------------ #
    def sorted_shards(self) -> list[Shard]:
        return sorted(self.shards, key=lambda s: (s.worker, s.doc_id, s.start))

    def describe(self) -> str:
        t = self.tokens_per_worker()
        w = self.workload_per_worker()
        lines = [
            f"ShardingPlan(N={self.num_workers}, C={self.context_len}, "
            f"docs={self.num_docs}, shards={len(self.shards)}, "
            f"comm={self.comm_style})",
            f"  tokens/worker   : {t.tolist()}",
            f"  workload/worker : {[int(x) for x in w]}",
            f"  imbalance ratio : {self.imbalance_ratio():.4f}",
            f"  comm tokens     : {self.comm_tokens()} "
            f"(static would be {self.context_len // self.num_workers})",
        ]
        return "\n".join(lines)


# ---------------------------------------------------------------------- #
# constructors & checks
# ---------------------------------------------------------------------- #
def make_whole_doc_plan(
    doc_lens: Sequence[int], assignment: Sequence[int], num_workers: int
) -> ShardingPlan:
    """Plan in which every document is kept whole on ``assignment[i]``."""
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    shards = [
        Shard(doc_id=i, start=0, length=int(doc_lens[i]), worker=int(assignment[i]))
        for i in range(len(doc_lens))
    ]
    return ShardingPlan(doc_lens=doc_lens, shards=shards, num_workers=num_workers)


def validate_plan(plan: ShardingPlan, *, require_equal_tokens: bool = True,
                  token_tolerance: int = 0) -> None:
    """Raise ``AssertionError`` unless the plan is well formed.

    Invariants (tested property-style in tests/test_planner.py):
      * shards of each document tile [0, d_i) exactly, without overlap;
      * every shard has positive length and a valid worker id;
      * (optionally) Eq. 2 — every worker holds C/N tokens, within
        ``token_tolerance`` (zigzag chunk remainders can leave a few
        tokens of slack, absorbed by execution-side padding).
    """
    by_doc: dict[int, list[Shard]] = {}
    for s in plan.shards:
        assert s.length > 0, f"empty shard {s}"
        assert 0 <= s.worker < plan.num_workers, f"bad worker in {s}"
        assert 0 <= s.doc_id < plan.num_docs, f"bad doc_id in {s}"
        by_doc.setdefault(s.doc_id, []).append(s)

    assert set(by_doc) == set(range(plan.num_docs)), "missing documents"
    for doc_id, shards in by_doc.items():
        shards = sorted(shards, key=lambda s: s.start)
        pos = 0
        for s in shards:
            assert s.start == pos, (
                f"doc {doc_id}: gap/overlap at {pos} (shard starts {s.start})"
            )
            pos = s.end
        assert pos == int(plan.doc_lens[doc_id]), (
            f"doc {doc_id}: covered {pos} of {int(plan.doc_lens[doc_id])} tokens"
        )

    if require_equal_tokens:
        t = plan.tokens_per_worker()
        c = plan.context_len
        n = plan.num_workers
        assert c % n == 0, f"context {c} not divisible by N={n}"
        assert int(t.max() - c // n) <= token_tolerance \
            and int(c // n - t.min()) <= token_tolerance, \
            f"equal-token constraint violated: {t.tolist()}"


def merge_adjacent_shards(shards: Iterable[Shard]) -> list[Shard]:
    """Merge shards of the same doc that are adjacent *and* co-located.

    The repair loop can produce e.g. [0,a)@w and [a,b)@w; merging keeps the
    kernel's shard count (and the comm accounting) minimal.
    """
    out: list[Shard] = []
    for s in sorted(shards, key=lambda s: (s.doc_id, s.start)):
        if out and out[-1].doc_id == s.doc_id and out[-1].end == s.start \
                and out[-1].worker == s.worker:
            prev = out.pop()
            s = Shard(s.doc_id, prev.start, prev.length + s.length, s.worker)
        out.append(s)
    return out
