"""Legacy import path — the exact branch-and-bound reference lives in
:mod:`repro.planner.ilp` (registry name ``"bnb"``)."""

import warnings

warnings.warn(
    "repro.core.ilp is deprecated; import from repro.planner.ilp instead",
    DeprecationWarning, stacklevel=2)

from repro.planner.ilp import BnBResult, bnb_plan  # noqa: F401

__all__ = ["bnb_plan", "BnBResult"]
