"""FlashCP heuristic sharding algorithm (paper Algorithm 1).

Faithful structure:

  1. Sort documents by decreasing length.
  2. Greedy LPT: assign each *whole* document to the CP worker with the
     minimum attention workload (``Min_Worker_Add``).
  3. Equal-token repair (``Whole_Doc_Shard_and_Add``): while token counts
     are unequal, move tokens from over-full to under-full workers.  Two
     move kinds, cheapest first:
       (a) relocate a whole document (zero communication cost);
       (b) cut a *head piece* off a document and move it — the donated head
           becomes a non-last shard (communication ∝ its length, the
           paper's Δl), while the bulk tail stays in place as a last shard
           (never communicated).
  4. If the resulting workload imbalance ratio exceeds the target ``R``,
     pop the longest document into the *Per-Doc* set (zigzag 2N-chunk
     sharding, perfectly balanced) and repeat from 2 with the remainder.

The returned :class:`~repro.core.plan.ShardingPlan` mixes Per-Doc zigzag
shards and Whole-Doc shards, exactly as §3.3 "Combine Per-Doc and Whole-Doc
Sharding" prescribes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .plan import Shard, ShardingPlan, merge_adjacent_shards, validate_plan
from .workload import shard_workload

__all__ = ["flashcp_plan", "zigzag_doc_shards", "HeuristicStats"]


@dataclasses.dataclass
class HeuristicStats:
    outer_iterations: int
    per_doc_docs: int
    whole_docs: int
    cut_docs: int
    imbalance_ratio: float
    comm_tokens: int


# --------------------------------------------------------------------- #
# Per-Doc zigzag sharding (used for extreme documents and by baselines)
# --------------------------------------------------------------------- #
def zigzag_doc_shards(doc_id: int, doc_len: int, num_workers: int) -> list[Shard]:
    """Split one document into 2N chunks; worker i gets chunks i and 2N-1-i.

    Pairing an early (cheap) with a late (expensive) chunk balances the
    causal attention workload across workers — the standard zigzag scheme
    of Per-Doc CP / Ring-Attn (Zigzag).
    """
    n2 = 2 * num_workers
    base, rem = divmod(doc_len, n2)
    sizes = [base + (1 if c < rem else 0) for c in range(n2)]
    starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    shards = []
    for c in range(n2):
        if sizes[c] == 0:
            continue
        worker = c if c < num_workers else n2 - 1 - c
        shards.append(Shard(doc_id=doc_id, start=int(starts[c]),
                            length=int(sizes[c]), worker=worker))
    return merge_adjacent_shards(shards)


# --------------------------------------------------------------------- #
# internal mutable state for the whole-doc phase
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class _Piece:
    """A (possibly cut) piece of a document living on one worker."""

    doc_id: int
    start: int
    length: int
    worker: int

    @property
    def end(self) -> int:
        return self.start + self.length

    def workload(self) -> float:
        return shard_workload(self.start, self.length)


class _State:
    def __init__(self, num_workers: int, base_tokens, base_workload,
                 doc_lens=None):
        self.N = num_workers
        self.pieces: list[_Piece] = []
        self.tokens = np.asarray(base_tokens, dtype=np.int64).copy()
        self.work = np.asarray(base_workload, dtype=np.float64).copy()
        self.doc_lens = doc_lens

    def is_last(self, piece: _Piece) -> bool:
        if self.doc_lens is None:
            return True
        return piece.end == int(self.doc_lens[piece.doc_id])

    def add(self, piece: _Piece) -> None:
        self.pieces.append(piece)
        self.tokens[piece.worker] += piece.length
        self.work[piece.worker] += piece.workload()

    def move(self, piece: _Piece, worker: int) -> None:
        self.tokens[piece.worker] -= piece.length
        self.work[piece.worker] -= piece.workload()
        piece.worker = worker
        self.tokens[worker] += piece.length
        self.work[worker] += piece.workload()

    def cut_head(self, piece: _Piece, size: int, receiver: int) -> _Piece:
        """Split ``size`` tokens off the front of ``piece``; move the head
        to ``receiver``.  The tail stays put (its prefix grows)."""
        assert 0 < size < piece.length
        donor = piece.worker
        head = _Piece(piece.doc_id, piece.start, size, receiver)
        # update tail in place
        old_w = piece.workload()
        piece.start += size
        piece.length -= size
        self.tokens[donor] -= size
        self.work[donor] += piece.workload() - old_w
        self.add(head)
        return head

    def cut_tail(self, piece: _Piece, size: int, receiver: int) -> _Piece:
        """Split ``size`` tokens off the end of ``piece``; move the tail to
        ``receiver``.  Cheaper than a head cut when size > length/2: the
        moved tail keeps the piece's last-shard status (never sent), and
        only the remaining head joins the communication set."""
        assert 0 < size < piece.length
        donor = piece.worker
        tail = _Piece(piece.doc_id, piece.end - size, size, receiver)
        old_w = piece.workload()
        piece.length -= size
        self.tokens[donor] -= size
        self.work[donor] += piece.workload() - old_w
        self.add(tail)
        return tail


# --------------------------------------------------------------------- #
# the algorithm
# --------------------------------------------------------------------- #
def flashcp_plan(
    doc_lens: Sequence[int],
    num_workers: int,
    *,
    target_ratio: float = 1.05,
    max_outer_iters: int | None = None,
    validate: bool = True,
) -> tuple[ShardingPlan, HeuristicStats]:
    """Run Algorithm 1 and return (plan, stats).

    ``doc_lens`` must sum to a context length divisible by ``num_workers``.
    """
    doc_lens = np.asarray(doc_lens, dtype=np.int64)
    n = len(doc_lens)
    ctx = int(doc_lens.sum())
    N = num_workers
    assert ctx % N == 0, f"context {ctx} not divisible by CP size {N}"
    per_worker = ctx // N
    if max_outer_iters is None:
        max_outer_iters = n + 1

    # documents sorted by decreasing length (line 1); ties broken by id for
    # determinism.
    order = sorted(range(n), key=lambda i: (-int(doc_lens[i]), i))

    per_doc_ids: list[int] = []      # Per_Doc_P (line 2/22)
    remaining: list[int] = list(order)

    state: _State | None = None
    outer = 0
    while True:
        outer += 1
        # ---- per-doc zigzag base load (from docs already popped).  The
        # 2N-chunk remainders are allocated jointly: each doc's extra
        # tokens go to the chunks of the currently least-loaded workers,
        # keeping the per-doc base within +-1 token of equal overall. ---- #
        base_tokens = np.zeros(N, dtype=np.int64)
        base_work = np.zeros(N, dtype=np.float64)
        per_doc_shards: list[Shard] = []
        n2 = 2 * N
        for did in per_doc_ids:
            d = int(doc_lens[did])
            base, rem = divmod(d, n2)
            sizes = [base] * n2
            worker_of = [c if c < N else n2 - 1 - c for c in range(n2)]
            if rem:
                chunk_order = sorted(
                    range(n2),
                    key=lambda c: (base_tokens[worker_of[c]], c))
                for c in chunk_order[:rem]:
                    sizes[c] += 1
            starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
            chunk_shards = [
                Shard(did, int(starts[c]), int(sizes[c]), worker_of[c])
                for c in range(n2) if sizes[c] > 0]
            for s in merge_adjacent_shards(chunk_shards):
                per_doc_shards.append(s)
                base_tokens[s.worker] += s.length
                base_work[s.worker] += s.workload()

        # ---- lines 5-9: greedy whole-doc LPT by attention workload ------ #
        state = _State(N, base_tokens, base_work, doc_lens)
        for did in remaining:
            j = int(np.argmin(state.work))
            state.add(_Piece(did, 0, int(doc_lens[did]), j))

        # ---- lines 10-16: equal-token repair ---------------------------- #
        _repair_equal_tokens(state, per_worker)

        # ---- beyond-paper refinement: comm-free workload exchange ------- #
        # Moving pieces between workers changes no shard's last-ness, so it
        # is (near-)free in Eq. 5 terms; exchanging a high-prefix piece on
        # the hottest worker against low-workload pieces on the coldest
        # often reaches the target ratio without popping documents into
        # Per-Doc sharding (which is what costs communication).
        _workload_exchange(state, per_worker, target_ratio)

        # ---- line 18: imbalance ratio of the full temporary plan -------- #
        work = state.work
        cur_ratio = float(np.max(work)) / max(float(np.mean(work)), 1e-9)

        if cur_ratio <= target_ratio or not remaining or outer >= max_outer_iters:
            break
        # ---- lines 19-23: pop the longest doc, shard it Per-Doc --------- #
        per_doc_ids.append(remaining.pop(0))

    # ---- build the final ShardingPlan ----------------------------------- #
    shards = list(per_doc_shards)
    shards.extend(
        Shard(p.doc_id, p.start, p.length, p.worker) for p in state.pieces
    )
    shards = merge_adjacent_shards(shards)
    plan = ShardingPlan(doc_lens=doc_lens, shards=shards, num_workers=N,
                        comm_style="flashcp")
    if validate:
        validate_plan(plan, token_tolerance=0 if not per_doc_ids else N)

    whole_docs = len({s.doc_id for s in shards
                      if s.start == 0 and s.length == doc_lens[s.doc_id]})
    stats = HeuristicStats(
        outer_iterations=outer,
        per_doc_docs=len(per_doc_ids),
        whole_docs=whole_docs,
        cut_docs=n - whole_docs,
        imbalance_ratio=plan.imbalance_ratio(),
        comm_tokens=plan.comm_tokens(),
    )
    return plan, stats


# --------------------------------------------------------------------- #
def _workload_exchange(state: _State, target_tokens: int,
                       target_ratio: float, max_iters: int = 40) -> None:
    """Reduce the attention-workload imbalance by exchanging pieces between
    the hottest and coldest workers (token counts re-repaired after each
    exchange).  Exchanges never change a piece's last-shard status, so the
    Eq. 5 communication set is essentially unchanged."""
    for _ in range(max_iters):
        work = state.work
        mean = float(np.mean(work))
        if mean <= 0 or float(np.max(work)) / mean <= target_ratio:
            return
        hot = int(np.argmax(work))
        cold = int(np.argmin(work))
        hot_pieces = [p for p in state.pieces if p.worker == hot]
        cold_pieces = [p for p in state.pieces if p.worker == cold]
        if not hot_pieces:
            return
        gap = work[hot] - work[cold]

        # best single-piece exchange (B may be 'nothing')
        best = None
        for A in hot_pieces:
            wa = A.workload()
            for B in cold_pieces + [None]:
                wb = B.workload() if B is not None else 0.0
                delta = wa - wb
                if delta <= 0 or delta >= gap:
                    continue  # must strictly shrink the gap
                score = abs(gap - 2 * delta)
                if best is None or score < best[0]:
                    best = (score, A, B)
        if best is None:
            return
        _, A, B = best
        state.move(A, cold)
        if B is not None:
            state.move(B, hot)
        _repair_equal_tokens(state, target_tokens)


def _repair_equal_tokens(state: _State, target: int) -> None:
    """``Whole_Doc_Shard_and_Add``: equalize token counts to ``target``.

    Strategy (cheapest communication first):
      1. relocate whole pieces donor→receiver when one fits the excess and
         the deficit (zero communication);
      2. cut head pieces of size min(excess, deficit) and move them (the
         donated head is a non-last shard; communication ∝ head length).

    Heads are preferentially cut from the piece whose transferred workload
    best levels the two workers' attention workloads, so token repair also
    nudges workload balance (Fig. 4(2) right: several small Δl cuts).
    """
    N = state.N
    guard = 0
    while True:
        guard += 1
        if guard > 100_000:  # pragma: no cover - safety net
            raise RuntimeError("token repair failed to converge")
        excess = state.tokens - target
        donor = int(np.argmax(excess))
        receiver = int(np.argmin(excess))
        if excess[donor] <= 0:
            assert np.all(excess == 0), f"tokens drifted: {state.tokens}"
            return
        need = int(min(excess[donor], -excess[receiver]))
        assert need > 0

        donor_pieces = [p for p in state.pieces if p.worker == donor]
        if not donor_pieces:
            # the excess sits entirely in per-doc zigzag base load (off by
            # at most a few tokens after joint remainder allocation);
            # execution-side padding absorbs it (plan_exec).
            return
        # (1) whole-piece relocation: largest piece that fits both sides.
        fits = [p for p in donor_pieces if p.length <= need]
        if fits:
            best = max(fits, key=lambda p: p.length)
            state.move(best, receiver)
            continue

        # (2) cut exactly `need` tokens off a piece.  Direction matters for
        # communication (Eq. 5):
        #   - cutting a piece that is already non-last adds NOTHING (its
        #     tokens were all in the send set already);
        #   - a last piece pays min(need, len - need): move the head (head
        #     joins the send set) or move the tail (the remaining head
        #     joins the send set) — pick the cheaper side.
        # Ties are broken toward leveling the donor/receiver workloads.
        candidates = [p for p in donor_pieces if p.length > need]
        assert candidates, "no piece can donate a cut"
        gap = state.work[donor] - state.work[receiver]

        def added_comm(p: _Piece) -> int:
            if not state.is_last(p):
                return 0
            return min(need, p.length - need)

        def level_score(p: _Piece) -> float:
            if state.is_last(p) and need > p.length - need:
                moved = shard_workload(p.end - need, need)   # tail cut
            else:
                moved = shard_workload(p.start, need)        # head cut
            return abs(gap - 2.0 * moved)

        best = min(candidates, key=lambda p: (added_comm(p),
                                              level_score(p)))
        if state.is_last(best) and need > best.length - need:
            state.cut_tail(best, need, receiver)
        else:
            state.cut_head(best, need, receiver)
