"""Legacy import path — Algorithm 1 lives in
:mod:`repro.planner.heuristic` (vectorized, registry-registered as
``"flashcp"``)."""

import warnings

warnings.warn(
    "repro.core.heuristic is deprecated; import from repro.planner.heuristic instead",
    DeprecationWarning, stacklevel=2)

from repro.planner.heuristic import (HeuristicStats,  # noqa: F401
                                     _ArrayState, _repair_equal_tokens,
                                     flashcp_plan, zigzag_doc_shards)

# the seed's mutable-state names, kept for external callers
_State = _ArrayState

__all__ = ["flashcp_plan", "zigzag_doc_shards", "HeuristicStats"]
