"""Paper Fig. 7 — FlashCP speedup across context window sizes (64K..128K),
8 CP workers, WLB-LLM.  The paper's observation: speedup grows with the
window because attention imbalance grows quadratically."""

from __future__ import annotations

import numpy as np

from repro.planner.baselines import BASELINE_PLANNERS
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence

from .cost_model import ModelDims, step_breakdown


def run() -> list[str]:
    rows = []
    dims = ModelDims(num_heads=32, kv_heads=8, head_dim=128)
    speedups = []
    for context in (65536, 98304, 131072):
        rng = make_rng(0)
        t = {m: [] for m in ("llama3", "per_doc", "flashcp")}
        for _ in range(12):
            lens = pack_sequence("wlb_llm", context, rng)
            for m in t:
                t[m].append(step_breakdown(
                    BASELINE_PLANNERS[m](lens, 8), dims)["total_s"])
        su_l3 = np.mean(t["llama3"]) / np.mean(t["flashcp"])
        su_pd = np.mean(t["per_doc"]) / np.mean(t["flashcp"])
        speedups.append(su_l3)
        rows.append(f"fig7_ctx{context//1024}k,"
                    f"{np.mean(t['flashcp'])*1e6:.0f},"
                    f"speedup_vs_llama3={su_l3:.2f};"
                    f"speedup_vs_perdoc={su_pd:.2f}")
    trend = "increasing" if speedups[-1] >= speedups[0] else "flat"
    rows.append(f"fig7_speedup_trend,,{trend}_paper_increasing")
    return rows
