"""Overlapped vs blocking CP execution + visit-table builder benchmarks.

Measures, on the simulated 4-way CPU CP mesh (subprocess, so the forced
device count never leaks into the caller's JAX runtime):

* wall-clock time of one flashcp attention step, blocking all-gather
  island (``overlap="none"``) vs chunked ppermute exchange
  (``overlap="chunked"``);
* **exposed** (un-overlapped) collective time and collective count of
  both lowered programs, via the two-resource schedule model of
  :mod:`repro.launch.hlo_analysis`;
* host time of the vectorized ``build_block_tables`` vs the legacy
  list-based builder at 131072 tokens / 128-token blocks (16-doc packed
  layout — the long-context regime FlashCP plans for).

Emits ``name,us_per_call,derived`` CSV rows (run.py suite ``overlap``)
and writes machine-readable ``BENCH_overlap.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
RESULT_JSON = os.path.join(ROOT, "BENCH_overlap.json")

N_CP = 4
CTX = 8192
DOC_LENS = [2500, 900, 1800, 1400, 700, 892]   # multi-doc long-context mix


def _child() -> None:
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh, set_mesh
    from repro.core.cp_attention import make_cp_context
    from repro.kernels.doc_attention import build_block_tables
    from repro.launch.hlo_analysis import analyze_hlo, schedule_model
    from repro.planner import encode_plan_batch, get_planner

    rng = np.random.default_rng(0)
    results: dict = {"config": {"cp": N_CP, "context_len": CTX,
                                "doc_lens": DOC_LENS}}

    # ---- blocking vs chunked flashcp execution ------------------------ #
    mesh = make_mesh((1, N_CP), ("data", "model"))
    doc_lens = np.asarray(DOC_LENS, np.int64)
    assert doc_lens.sum() == CTX
    plan = get_planner("flashcp")(doc_lens, N_CP)
    stack, _ = encode_plan_batch([plan], align=128)
    arrays = {k: jnp.asarray(v) for k, v in stack.items()}
    C_pad = stack["doc"].shape[1]
    B, HQ, HKV, D = 1, 4, 2, 64
    sh = NamedSharding(mesh, P(None, None, "model", None))
    q = jax.device_put(jnp.asarray(
        rng.standard_normal((B, HQ, C_pad, D)).astype(np.float32)), sh)
    k = jax.device_put(jnp.asarray(
        rng.standard_normal((B, HKV, C_pad, D)).astype(np.float32)), sh)
    v = jax.device_put(jnp.asarray(
        rng.standard_normal((B, HKV, C_pad, D)).astype(np.float32)), sh)

    exec_res = {}
    for ov in ("none", "chunked"):
        with set_mesh(mesh):
            ctx = make_cp_context(mesh, arrays, strategy="flashcp",
                                  impl="xla", batch_axes=(None,),
                                  head_dim=D, q_chunk=512, overlap=ov)
            fn = jax.jit(ctx.attn)
            fn(q, k, v).block_until_ready()        # compile + warm
            times = []
            for _ in range(5):
                t0 = time.perf_counter()
                fn(q, k, v).block_until_ready()
                times.append(time.perf_counter() - t0)
            txt = fn.lower(q, k, v).compile().as_text()
        sc = schedule_model(txt)
        hc = analyze_hlo(txt)
        exec_res[ov] = {
            "wallclock_us": min(times) * 1e6,
            "exposed_comm_us": sc.exposed_comm_s * 1e6,
            "comm_busy_us": sc.comm_busy_s * 1e6,
            "modeled_makespan_us": sc.makespan_s * 1e6,
            "collective_count": sc.collective_count,
            "collective_wire_bytes": hc.collective_wire_bytes,
        }
        print(f"overlap_exec_{ov}_wallclock,"
              f"{exec_res[ov]['wallclock_us']:.0f},")
        print(f"overlap_exec_{ov}_exposed_comm_us,,"
              f"{exec_res[ov]['exposed_comm_us']:.2f}")
        print(f"overlap_exec_{ov}_collectives,,"
              f"{exec_res[ov]['collective_count']:.0f}")
    reduction = (exec_res["none"]["exposed_comm_us"]
                 / max(exec_res["chunked"]["exposed_comm_us"], 1e-9))
    exec_res["exposed_comm_reduction_x"] = reduction
    print(f"overlap_exposed_comm_reduction,,{reduction:.2f}x")
    results["execution"] = exec_res

    # ---- vectorized vs legacy build_block_tables ---------------------- #
    T, blk, n_docs = 131072, 128, 16
    d = np.repeat(np.arange(n_docs, dtype=np.int32), T // n_docs)[None]
    p = np.tile(np.arange(T // n_docs, dtype=np.int32), n_docs)[None]

    def best(f, n):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            f()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    tv = best(lambda: build_block_tables(d, p, d, p, block_q=blk,
                                         block_k=blk), 5)
    tl = best(lambda: build_block_tables(d, p, d, p, block_q=blk,
                                         block_k=blk, legacy=True), 3)
    a = build_block_tables(d, p, d, p, block_q=blk, block_k=blk)
    b = build_block_tables(d, p, d, p, block_q=blk, block_k=blk,
                           legacy=True)
    parity = all(np.array_equal(getattr(a, n), getattr(b, n))
                 for n in ("kv_idx", "kv_nvis", "q_idx", "q_nvis"))
    results["block_tables"] = {
        "tokens": T, "block": blk, "num_docs": n_docs,
        "vectorized_us": tv * 1e6, "legacy_us": tl * 1e6,
        "speedup_x": tl / tv, "parity": parity,
    }
    print(f"block_tables_vectorized_131k,{tv*1e6:.0f},")
    print(f"block_tables_legacy_131k,{tl*1e6:.0f},")
    print(f"block_tables_speedup,,{tl/tv:.1f}x")
    print(f"block_tables_parity,,{parity}")

    with open(RESULT_JSON, "w") as f:
        json.dump(results, f, indent=1)
    print(f"overlap_json,,{RESULT_JSON}")


def run():
    """run.py suite entry: spawn the forced-device-count child and relay
    its CSV rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_overlap", "--child"],
        capture_output=True, text=True, timeout=1800, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_overlap child failed:\n{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.count(",") == 2:
            yield line


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child()
    else:
        for row in run():
            print(row)
