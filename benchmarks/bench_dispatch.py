"""Dispatch suite (``dispatch``, ``BENCH_dispatch.json``): adaptive DP×CP
token dispatch vs the static per-rank path.

Host-side section (pure numpy, real planner output): for three document
mixes — uniform, heavy-tail, short-doc — compare

* **static**: every DP rank samples/packs its windows independently and
  plans at the full ``model`` CP axis (the legacy ``make_batch`` world);
* **dispatch**: one global pool per step, CP degree sized to the mix,
  documents LPT-balanced across the DP×CP groups
  (:func:`repro.dispatch.dispatch_step`).

Reported per mix: the chosen CP degree, cross-rank (per-group) max/mean
token imbalance, per-*device* attention-workload imbalance (computed from
each sequence's real plan — step time is the max over devices), and the
stepped KV-exchange volume in bytes (Eq. 4/5 accounting over real plans,
summed over every sequence of the step).  The dispatcher's host cost per
step is timed alongside.

Parity section (subprocess with simulated devices, like bench_overlap):
the same pool dispatched at two degrees — small groups vs the full-axis
static tiling — must produce the same token-weighted loss and gradient
norm through the real CP train path on the re-tiled meshes.

Emits ``name,us_per_call,derived`` CSV rows (run.py suite ``dispatch``)
and writes machine-readable ``BENCH_dispatch.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
RESULT_JSON = os.path.join(ROOT, "BENCH_dispatch.json")

# representative GQA geometry for the byte accounting (Eq. 4/5)
KV_HEADS, HEAD_DIM = 8, 128


def _mix_samplers(C: int) -> dict:
    """Per-mix document-length samplers (token counts)."""
    return {
        "uniform": lambda rng: int(np.clip(
            rng.lognormal(np.log(C / 16), 0.25), 64, C)),
        "heavy_tail": lambda rng: int(rng.integers(C // 2, C))
        if rng.random() < 0.08 else int(np.clip(
            rng.lognormal(np.log(C / 64), 0.8), 64, C)),
        "short_doc": lambda rng: int(rng.integers(64, 384)),
    }


def _device_workloads(plans, groups, cp: int, n_devices: int) -> np.ndarray:
    """Per-device attention workload: each sequence's plan spreads its
    workload over its group's ``cp`` devices."""
    load = np.zeros(n_devices)
    for plan, g in zip(plans, groups):
        load[g * cp: (g + 1) * cp] += plan.workload_per_worker()
    return load


def _comm_volume(plans) -> int:
    """Stepped KV-exchange volume: Eq. 4/5 bytes summed over the step's
    sequences (each plan knows its own comm style and degree)."""
    from repro.core.workload import plan_comm_bytes
    return int(sum(plan_comm_bytes(p, KV_HEADS, HEAD_DIM) for p in plans))


def _static_side(name, sampler, D, M, seqs, C, planner):
    """Legacy path: per-rank independent packing, full-axis CP."""
    from repro.data.distributions import DATASETS, make_rng
    from repro.data.packing import pack_sequence
    from repro.dispatch import imbalance

    DATASETS[f"_bench_{name}"] = sampler
    try:
        per_rank = seqs // D
        rows, groups = [], []
        for r in range(D):
            rng = make_rng(hash((1234, r, 0)) % (2 ** 63))
            for _ in range(per_rank):
                rows.append(pack_sequence(f"_bench_{name}", C, rng))
                groups.append(r)
    finally:
        del DATASETS[f"_bench_{name}"]
    plans = [planner(lens, M) for lens in rows]
    dev = _device_workloads(plans, groups, M, D * M)
    rank_tokens = np.asarray(
        [sum(int(r.sum()) for r, g in zip(rows, groups) if g == rr)
         for rr in range(D)])
    return {
        "cp_degree": M,
        "n_groups": D,
        "token_imbalance": imbalance(rank_tokens),
        "device_work_imbalance": imbalance(dev),
        "comm_volume_bytes": _comm_volume(plans),
        "tokens": int(sum(int(r.sum()) for r in rows)),
    }


def _dispatch_side(name, sampler, D, M, seqs, C, planner):
    from repro.data.distributions import DATASETS, make_rng
    from repro.data.packing import sample_doc_pool
    from repro.dispatch import DispatchConfig, dispatch_step, imbalance

    DATASETS[f"_bench_{name}"] = sampler
    try:
        rng = make_rng(hash((1234, -1, 0)) % (2 ** 63))
        pool = sample_doc_pool(f"_bench_{name}", seqs * C, rng,
                               max_doc_len=C)
    finally:
        del DATASETS[f"_bench_{name}"]
    dcfg = DispatchConfig(data=D, model=M, seqs=seqs,
                          target_imbalance=1.1, quantum=16)
    t0 = time.perf_counter()
    dplan = dispatch_step(pool, dcfg, C)
    host_us = (time.perf_counter() - t0) * 1e6
    g = dplan.cp_degree
    plans = [planner(lens, g) for lens in dplan.rows]
    spg = dplan.seqs_per_group
    groups = [r // spg for r in range(seqs)]
    dev = _device_workloads(plans, groups, g, D * M)
    return {
        "cp_degree": g,
        "n_groups": dplan.n_groups,
        "token_imbalance": imbalance(dplan.group_tokens),
        "device_work_imbalance": imbalance(dev),
        "comm_volume_bytes": _comm_volume(plans),
        "tokens": int(dplan.group_tokens.sum()),
        "truncated_tokens": dplan.truncated_tokens,
        "dispatch_host_us": host_us,
        "candidates": dplan.candidates,
    }


def _parity_child() -> None:
    """Runs under 8 forced CPU devices: the same pool dispatched at CP 2
    (4 groups) and CP 4 (2 groups — the static full-axis tiling) must
    give the same token-weighted loss and grad norm."""
    import jax
    import jax.numpy as jnp

    from repro.compat import set_mesh
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.cp_attention import make_cp_context
    from repro.data.pipeline import PipelineConfig, make_dispatch_batch
    from repro.dispatch import DispatchConfig
    from repro.launch.mesh import make_group_mesh
    from repro.models import init_params, loss_fn
    from repro.optim import global_norm

    import dataclasses
    cfg = dataclasses.replace(reduce_for_smoke(get_config("starcoder2_3b")),
                              dtype="float32")
    C, seqs, D, M = 512, 4, 2, 4
    pipe = PipelineConfig(dataset="pile", context_len=C, batch_per_host=seqs,
                          cp_size=M, strategy="flashcp",
                          vocab_size=cfg.vocab_size, seed=11, align=16)
    params = init_params(jax.random.PRNGKey(0), cfg)

    out = {}
    for g in (2, 4):
        # degree-invariant packing (lcm bin quantum): both tilings see
        # the same documents, so loss/grad must agree
        dcfg = DispatchConfig(data=D, model=M, seqs=seqs, fixed_cp=g,
                              bin_quantum=4)
        batch = make_dispatch_batch(pipe, dcfg, step=0)
        mesh = make_group_mesh(D, M, g)
        arrays = {k: jnp.asarray(v) for k, v in batch.items()
                  if k != "stats" and not k.startswith(("seq_", "group_"))}
        with set_mesh(mesh):
            ctx = make_cp_context(
                mesh, {k: arrays[k] for k in ("doc", "pos", "send_idx",
                                              "gath_doc", "gath_pos")},
                strategy="flashcp", impl="xla", batch_axes=("data",),
                head_dim=cfg.resolved_head_dim, q_chunk=64)

            @jax.jit
            def lg(p, b):
                (l, _), grads = jax.value_and_grad(
                    lambda pp: loss_fn(pp, cfg, ctx, b, remat=False),
                    has_aux=True)(p)
                return l, global_norm(grads)

            loss, gn = lg(params, {k: arrays[k]
                                   for k in ("tokens", "labels")})
        out[g] = (float(loss), float(gn))

    (l2, g2), (l4, g4) = out[2], out[4]
    print(json.dumps({
        "loss_cp2": l2, "loss_cp4": l4,
        "gnorm_cp2": g2, "gnorm_cp4": g4,
        "loss_rel_diff": abs(l2 - l4) / max(abs(l4), 1e-9),
        "gnorm_rel_diff": abs(g2 - g4) / max(abs(g4), 1e-9),
    }))


def run(smoke: bool = False):
    from repro.planner import get_planner

    D, M = (2, 4) if smoke else (2, 8)
    seqs = 8 if smoke else 16
    C = 2048 if smoke else 16384
    planner = get_planner("flashcp")

    results: dict = {"config": {"data": D, "model": M, "seqs": seqs,
                                "context_len": C, "kv_heads": KV_HEADS,
                                "head_dim": HEAD_DIM}, "mixes": {}}
    rows = []
    for name, sampler in _mix_samplers(C).items():
        st = _static_side(name, sampler, D, M, seqs, C, planner)
        dy = _dispatch_side(name, sampler, D, M, seqs, C, planner)
        comm_red = st["comm_volume_bytes"] / max(dy["comm_volume_bytes"], 1)
        work_red = st["device_work_imbalance"] / dy["device_work_imbalance"]
        results["mixes"][name] = {"static": st, "dispatch": dy,
                                  "comm_reduction_x": comm_red,
                                  "work_imbalance_reduction_x": work_red}
        rows.append(f"dispatch_{name}_cp_degree,,{dy['cp_degree']}")
        rows.append(f"dispatch_{name}_token_imb,,"
                    f"{dy['token_imbalance']:.3f}")
        rows.append(f"dispatch_{name}_token_imb_static,,"
                    f"{st['token_imbalance']:.3f}")
        rows.append(f"dispatch_{name}_work_imb,,"
                    f"{dy['device_work_imbalance']:.3f}")
        rows.append(f"dispatch_{name}_work_imb_static,,"
                    f"{st['device_work_imbalance']:.3f}")
        rows.append(f"dispatch_{name}_comm_bytes,,"
                    f"{dy['comm_volume_bytes']}")
        rows.append(f"dispatch_{name}_comm_bytes_static,,"
                    f"{st['comm_volume_bytes']}")
        rows.append(f"dispatch_{name}_comm_reduction,,{comm_red:.2f}x")
        rows.append(f"dispatch_{name}_host,"
                    f"{dy['dispatch_host_us']:.0f},")

    # fwd+grad parity across group tilings (simulated-device subprocess,
    # so the forced device count never leaks into the caller's runtime)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--parity-child"],
        capture_output=True, text=True, env=env, check=True)
    parity = json.loads(proc.stdout.strip().splitlines()[-1])
    results["parity"] = parity
    rows.append(f"dispatch_parity_loss_rel_diff,,"
                f"{parity['loss_rel_diff']:.2e}")
    rows.append(f"dispatch_parity_gnorm_rel_diff,,"
                f"{parity['gnorm_rel_diff']:.2e}")

    with open(RESULT_JSON, "w") as f:
        json.dump(results, f, indent=1)
    rows.append(f"dispatch_json,,{os.path.basename(RESULT_JSON)}")
    return rows


if __name__ == "__main__":
    if "--parity-child" in sys.argv:
        _parity_child()
    else:
        for row in run(smoke="--smoke" in sys.argv):
            print(row)
