"""Autotune suite (``autotune``, ``BENCH_autotune.json``): predicted-vs-
measured rank quality of the config tuner on a brute-forceable space.

For a CPU-scale problem (1x2 mesh, Pallas tables, 1K context) and two
document-length profiles — ``uniform_short`` (lognormal body, no tail)
and ``heavy_tail`` (two near-window docs over a short body) — this
suite:

* enumerates the full admissible candidate space
  (:func:`repro.autotune.enumerate_candidates`),
* scores every candidate with both the analytic predictor
  (:func:`repro.autotune.predict`) and the measured trial
  (:func:`repro.autotune.measure_candidate` — real encodings + emitted
  visit tables), i.e. *brute-force measures the whole space*,
* reports the full-space Spearman rank correlation between the two
  scores (the acceptance headline: >= 0.8), and
* runs the actual two-stage tuner (:func:`repro.autotune.tune`,
  predict -> top-K prune -> measure) and checks its pick against the
  exhaustive-measurement optimum.

Emits ``name,us_per_call,derived`` CSV rows (run.py suite ``autotune``)
and writes machine-readable ``BENCH_autotune.json`` at the repo root.
``--smoke`` shrinks the space (two strategies, one dispatch target) for
CI tier-2; the full run is the committed artifact.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
RESULT_JSON = os.path.join(ROOT, "BENCH_autotune.json")

TOP_K = 8


def _profiles() -> dict:
    """Two deterministic document pools (token lengths)."""
    uniform = np.clip(np.random.default_rng(42)
                      .lognormal(4.0, 0.6, 40).astype(int), 16, 256)
    tail = np.concatenate([
        [900, 800],
        np.clip(np.random.default_rng(7)
                .lognormal(3.5, 0.8, 30).astype(int), 16, 256)])
    return {"uniform_short": uniform, "heavy_tail": tail}


def run(smoke: bool = False):
    from repro.autotune import (DEFAULT_SPACE, ModelDims, SearchSpace,
                                TuneProblem, brute_force,
                                enumerate_candidates, measure_candidate,
                                predict, spearman, tune)

    problem = TuneProblem(data=1, model=2, context_len=1024, seqs=2,
                          quantum=128, attention_impl="pallas",
                          family="dense")
    dims = ModelDims(num_heads=8, kv_heads=4, head_dim=64,
                     d_model=512, d_ff=2048)
    space = SearchSpace(strategies=("flashcp", "contiguous"),
                        dispatch_targets=(1.1,)) if smoke else DEFAULT_SPACE

    rows = []
    results = {"problem": problem.as_dict(),
               "dims": {"num_heads": dims.num_heads,
                        "kv_heads": dims.kv_heads,
                        "head_dim": dims.head_dim,
                        "d_model": dims.d_model, "d_ff": dims.d_ff},
               "top_k": TOP_K, "smoke": smoke, "profiles": {}}

    for name, pool in _profiles().items():
        cands = enumerate_candidates(problem, space)
        t0 = time.time()
        preds = [predict(c, pool, problem, dims) for c in cands]
        predict_us = (time.time() - t0) / len(cands) * 1e6
        t0 = time.time()
        meas = [measure_candidate(c, pool, problem, dims) for c in cands]
        measure_us = (time.time() - t0) / len(cands) * 1e6

        rho = spearman([p.step_s for p in preds],
                       [m.step_s for m in meas])
        opt, opt_cost = brute_force(cands, meas)

        t0 = time.time()
        res = tune(pool, problem, dims, space=space, top_k=TOP_K)
        tune_us = (time.time() - t0) * 1e6
        match = res.best.key() == opt.key()
        regret = res.best_measured["step_s"] / opt_cost.step_s - 1.0

        rows.append(f"autotune_{name}_candidates,,{len(cands)}")
        rows.append(f"autotune_{name}_predict,{predict_us:.0f},per_cand")
        rows.append(f"autotune_{name}_measure,{measure_us:.0f},per_cand")
        rows.append(f"autotune_{name}_spearman_full,,{rho:.4f}")
        rows.append(f"autotune_{name}_tuner_matches_optimum,,{int(match)}")
        rows.append(f"autotune_{name}_tuner_regret,,{regret:.4f}")
        rows.append(f"autotune_{name}_tune_wallclock,{tune_us:.0f},")
        rows.append(f"autotune_{name}_best,,"
                    f"{'/'.join(str(k) for k in res.best.key())}")

        results["profiles"][name] = {
            "n_candidates": len(cands),
            "spearman_full_space": round(rho, 4),
            "spearman_frontier": round(res.spearman_frontier, 4),
            "tuner_matches_optimum": bool(match),
            "tuner_regret": round(regret, 6),
            "optimum": opt.as_dict(),
            "tuner_best": res.best.as_dict(),
            "optimum_step_us": round(opt_cost.step_s * 1e6, 3),
            "tuner_step_us": round(res.best_measured["step_s"] * 1e6, 3),
            "signature_key": res.key,
        }

    if not smoke:
        with open(RESULT_JSON, "w") as f:
            json.dump(results, f, indent=1)
        rows.append(f"autotune_json,,{os.path.basename(RESULT_JSON)}")
    return rows
