"""Paper Fig. 6 — training latency breakdown (comm / attention / other)
for Llama3 CP, Per-Doc CP and FlashCP on WLB-LLM and Pile, 8 CP workers,
128K context (the paper's intra-node setting)."""

from __future__ import annotations

import numpy as np

from repro.planner.baselines import BASELINE_PLANNERS
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence

from .cost_model import ModelDims, step_breakdown

METHODS = ["llama3", "per_doc", "flashcp"]


def run() -> list[str]:
    rows = []
    dims = ModelDims(num_heads=32, kv_heads=8, head_dim=128)
    for dataset in ("wlb_llm", "pile"):
        rng = make_rng(0)
        acc = {m: {"comm_s": [], "attn_s": [], "other_s": []}
               for m in METHODS}
        for _ in range(12):
            lens = pack_sequence(dataset, 131072, rng)
            for m in METHODS:
                bd = step_breakdown(BASELINE_PLANNERS[m](lens, 8), dims)
                for k in ("comm_s", "attn_s", "other_s"):
                    acc[m][k].append(bd[k])
        for m in METHODS:
            comm = np.mean(acc[m]["comm_s"]) * 1e6
            attn = np.mean(acc[m]["attn_s"]) * 1e6
            other = np.mean(acc[m]["other_s"]) * 1e6
            rows.append(f"fig6_breakdown_{dataset}_{m},"
                        f"{comm+attn+other:.0f},"
                        f"comm_us={comm:.0f};attn_us={attn:.0f};"
                        f"other_us={other:.0f}")
        # the paper's headline: FlashCP comm reduction vs full exchange
        red = 1 - np.mean(acc["flashcp"]["comm_s"]) / \
            np.mean(acc["llama3"]["comm_s"])
        rows.append(f"fig6_comm_reduction_{dataset},,"
                    f"{red:.1%}_paper_23.6%_wlb_34.5%_pile")
    return rows
