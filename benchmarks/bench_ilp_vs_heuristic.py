"""Paper Table 2 — optimality study: exact solver vs heuristic.

Pile-like mixes, 4 CP workers (the paper's setting).  The exact reference
is the branch-and-bound optimizer (core/ilp.py; no MILP package offline —
DESIGN.md §8).  Metrics match the paper: communication saving vs the
static full exchange, and workload imbalance ratio; plus wall-clock of
both solvers (the paper's point: ILP takes tens of minutes, the heuristic
is effectively free)."""

from __future__ import annotations

import time

import numpy as np

from repro.planner import bnb_plan, get_planner
from repro.core.workload import comm_saving
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence


def run() -> list[str]:
    heuristic = get_planner("flashcp")
    rng = make_rng(0)
    # small instances keep the exact search tractable (scaled-down C, as
    # the paper scales time by using a commercial solver for minutes)
    h_save, h_imb, b_save, b_imb = [], [], [], []
    t_h = t_b = 0.0
    n = 6
    for _ in range(n):
        lens = pack_sequence("pile", 8192, rng)
        # merge smallest docs to keep <= 9 docs for exactness
        lens = np.sort(lens)[::-1]
        while len(lens) > 9:
            lens = np.sort(np.concatenate([lens[:-2], [lens[-1] + lens[-2]]])
                           )[::-1]
        t0 = time.perf_counter()
        plan = heuristic(lens, 4)
        t_h += time.perf_counter() - t0
        t0 = time.perf_counter()
        res = bnb_plan(lens, 4, lambda_comm=0.5, max_nodes=400_000)
        t_b += time.perf_counter() - t0
        h_save.append(comm_saving(plan))
        h_imb.append(plan.imbalance_ratio())
        b_save.append(comm_saving(res.plan))
        b_imb.append(res.plan.imbalance_ratio())
    return [
        f"table2_heuristic,{t_h/n*1e6:.0f},"
        f"comm_saving={np.mean(h_save):.1%};imbalance={np.mean(h_imb):.3f}"
        f"_paper_28%_1.04",
        f"table2_exact_bnb,{t_b/n*1e6:.0f},"
        f"comm_saving={np.mean(b_save):.1%};imbalance={np.mean(b_imb):.3f}"
        f"_paper_36%_1.00",
    ]
