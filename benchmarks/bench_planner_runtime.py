"""Planner+encoder throughput: the host-side planning stage must be
negligible next to a training step (it runs per packed sequence inside the
input pipeline, on the critical path — the input-dynamism cost DCP/ByteScale
identify as dominant at scale).

Measures the *pipeline planning+encoding stage* — doc-length mix in,
stacked device arrays out, exactly what ``repro.data.pipeline.make_batch``
runs per step — at context_len=131072, cp=16, align=128, and compares:

* ``seed``   — the frozen seed implementation
  (:mod:`repro.planner.reference`): per-``Shard``-object planning plus the
  seed's double-pass batch encoder;
* ``cold``   — the vectorized :mod:`repro.planner` subsystem, empty cache
  (pure algorithmic speedup; plans are shard-for-shard identical to seed,
  enforced by tests/test_planner_registry.py);
* ``steady`` — the subsystem as the pipeline ships it, with the
  ``PlanCache`` warm — the steady-state cost of replayed / recurring
  mixes (restart replay, elastic re-planning, straggler-driven re-plans
  of the same packed batch).

All timings are best-of-``REPS`` per-sequence milliseconds; speedups are
seed/new.  The headline ``planner_encode_speedup`` row reports the
steady-state pipeline speedup with the cold-path speedup alongside.
"""

from __future__ import annotations

import time

import numpy as np

from repro.planner import PlanCache, encode_plan_batch, get_planner
from repro.planner import reference as ref
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence

CONTEXT = 131072
CP = 16
ALIGN = 128
SEQS = 8
REPS = 4


def _best_of(fn, reps: int = REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _seed_stage(seqs):
    plans = [ref.ref_flashcp_plan(lens, CP) for lens in seqs]
    ref.ref_encode_plan_batch(plans, align=ALIGN)


def _cold_stage(seqs, planner):
    plans = [planner(lens, CP) for lens in seqs]
    encode_plan_batch(plans, align=ALIGN)


def _steady_stage(seqs, cache):
    plans = [cache.plan(lens) for lens in seqs]
    encode_plan_batch(plans, align=ALIGN)


def run() -> list[str]:
    rows = []
    planner = get_planner("flashcp")
    for dataset in ("wlb_llm", "pile"):
        rng = make_rng(0)
        seqs = [pack_sequence(dataset, CONTEXT, rng) for _ in range(SEQS)]
        docs_mean = float(np.mean([len(s) for s in seqs]))

        t_seed = _best_of(lambda: _seed_stage(seqs)) / SEQS
        t_cold = _best_of(lambda: _cold_stage(seqs, planner)) / SEQS
        cache = PlanCache(planner, CP)
        for lens in seqs:
            cache.plan(lens)          # warm: replayed-step signatures
        t_steady = _best_of(lambda: _steady_stage(seqs, cache)) / SEQS

        rows.append(
            f"planner_encode_seed_{dataset}_cp{CP},{t_seed*1e6:.0f},"
            f"docs_mean={docs_mean:.0f}")
        rows.append(
            f"planner_encode_cold_{dataset}_cp{CP},{t_cold*1e6:.0f},"
            f"speedup_vs_seed={t_seed/t_cold:.2f}x")
        rows.append(
            f"planner_encode_steady_{dataset}_cp{CP},{t_steady*1e6:.0f},"
            f"speedup_vs_seed={t_seed/t_steady:.2f}x;"
            f"cache_hit_rate={cache.stats.hit_rate:.2f}")
        rows.append(
            f"planner_encode_speedup_{dataset}_context{CONTEXT},,"
            f"steady_state={t_seed/t_steady:.1f}x;"
            f"cold={t_seed/t_cold:.1f}x_vs_seed")
    return rows
