"""Planner throughput: Algorithm 1 must be negligible next to a training
step (it runs on host per packed sequence inside the input pipeline)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.heuristic import flashcp_plan
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence


def run() -> list[str]:
    rows = []
    for dataset in ("wlb_llm", "pile"):
        rng = make_rng(0)
        seqs = [pack_sequence(dataset, 131072, rng) for _ in range(10)]
        t0 = time.perf_counter()
        for lens in seqs:
            flashcp_plan(lens, 16)
        dt = (time.perf_counter() - t0) / len(seqs)
        rows.append(f"planner_runtime_{dataset}_cp16,{dt*1e6:.0f},"
                    f"docs_mean={np.mean([len(s) for s in seqs]):.0f}")
    return rows
