"""Elastic-recovery suite (``elastic``, ``BENCH_elastic.json``):
degree-replanning recovery + straggler-weighted balancing ground truth
(DESIGN.md §Recovery).

Three sections:

* **weighted LPT** (host-side numpy, real document pools): a 2x-slow
  group makes plain load-balanced LPT assignment ~2x *completion*-time
  imbalanced; capacity-proportional LPT (``lpt_assign(speeds=...)``)
  routes proportionally less work onto the slow group and pulls the
  speed-normalized (effective) imbalance back toward 1.  Also exercised
  end-to-end: a :class:`repro.runtime.StragglerMonitor` fed simulated
  2x-slow host step times produces the speed vector, and
  :func:`repro.dispatch.dispatch_step` consumes it live.

* **recovery throughput** (subprocess children under 8 forced CPU
  devices, the real ``--dispatch`` training driver): one run loses a
  host mid-run (``--fail-at K:3``) and elastically shrinks; one hits a
  transient fault at the same step (``--fail-at K``) and restarts on the
  full grid; one runs uninterrupted (oracle).  Per-step wall times are
  parsed from the driver's logs; reported are pre-failure vs
  post-recovery steps/s for both recovery modes.  Simulated host devices
  share one CPU, so the *measured* post-shrink rate barely moves — the
  capacity model (surviving/total devices) is reported alongside as the
  projected shrink on real hardware.

* **loss parity**: the interrupted+shrunk run must land on the oracle's
  loss trajectory — the deterministic (seed, step) stream plus
  reshard-on-restore plus token-weighted gradient accumulation make the
  replayed steps bit-identical and the post-shrink tail fp-close.

Emits ``name,us_per_call,derived`` CSV rows (run.py suite ``elastic``)
and writes machine-readable ``BENCH_elastic.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import tempfile

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
RESULT_JSON = os.path.join(ROOT, "BENCH_elastic.json")

STEP_RE = re.compile(r"\[train\] step\s+(\d+) .*?([0-9.]+)s\s*$")
RESTORE_RE = re.compile(r"\[train\] restored step (\d+)")


# --------------------------------------------------------------------- #
# section 1: straggler-weighted LPT (host-side)
# --------------------------------------------------------------------- #
def _weighted_lpt_section(rng: np.random.Generator) -> dict:
    from repro.dispatch import effective_imbalance, lpt_assign
    from repro.runtime import StragglerMonitor

    # heavy-tail document workload pool, 4 groups, group 3 at half speed
    n_groups, slow = 4, 3
    weights = np.clip(rng.lognormal(8.0, 1.0, size=96), 64, 1e5)
    speeds = np.ones(n_groups)
    speeds[slow] = 0.5

    plain = lpt_assign(weights, n_groups)
    weighted = lpt_assign(weights, n_groups, speeds=speeds)

    def group_loads(assign):
        return np.bincount(assign, weights=weights, minlength=n_groups)

    out = {
        "n_groups": n_groups,
        "slow_group": slow,
        "slow_factor": 2.0,
        "unweighted_effective_imbalance":
            float(effective_imbalance(group_loads(plain), speeds)),
        "weighted_effective_imbalance":
            float(effective_imbalance(group_loads(weighted), speeds)),
        "unweighted_raw_imbalance":
            float(effective_imbalance(group_loads(plain))),
        "weighted_raw_imbalance":
            float(effective_imbalance(group_loads(weighted))),
    }

    # live path: monitor EMAs -> host speed vector -> dispatcher
    mon = StragglerMonitor()
    for _ in range(12):
        for h in range(n_groups):
            mon.record_host_step(h, 2.0 if h == slow else 1.0)
    mon_speeds = mon.host_speeds(range(n_groups))
    out["monitor_speeds"] = [round(float(s), 4) for s in mon_speeds]

    dispatched = _dispatch_with_speeds(mon_speeds)
    out.update(dispatched)
    return out


def _dispatch_with_speeds(host_speeds: np.ndarray) -> dict:
    """The full dispatcher on a real pool, unweighted vs monitor-weighted
    (4 simulated hosts x 2 devices on a 4x2 grid)."""
    from repro.data.distributions import make_rng
    from repro.data.packing import sample_doc_pool
    from repro.dispatch import (DispatchConfig, dispatch_step,
                                effective_imbalance)

    D, M, seqs, C = 4, 2, 16, 2048
    pool = sample_doc_pool("wlb_llm", seqs * C, make_rng(7), max_doc_len=C,
                           min_docs=seqs)
    dcfg = DispatchConfig(data=D, model=M, seqs=seqs, quantum=16)
    dev_speeds = np.repeat(np.asarray(host_speeds, float), 2)

    def eff_under_truth(plan):
        """The plan's completion-time imbalance under the *true* speeds
        (the unweighted dispatcher never sees them — this is what the
        slow host actually costs its placement)."""
        g, n_groups = plan.cp_degree, plan.n_groups
        gs = dev_speeds[:n_groups * g].reshape(n_groups, g).min(axis=1)
        return float(effective_imbalance(plan.group_workload,
                                         gs / gs.max()))

    plain = dispatch_step(pool, dcfg, C)
    weighted = dispatch_step(pool, dcfg, C, device_speeds=dev_speeds)
    return {
        "dispatch_unweighted_work_imbalance": eff_under_truth(plain),
        "dispatch_unweighted_work_imbalance_raw":
            float(plain.work_imbalance),
        # the weighted plan's work_imbalance is already effective
        # (speed-normalized); _raw is its plain load ratio
        "dispatch_weighted_work_imbalance": float(weighted.work_imbalance),
        "dispatch_weighted_work_imbalance_raw":
            float(weighted.stats().get("work_imbalance_raw",
                                       weighted.work_imbalance)),
        "dispatch_cp_degree": int(weighted.cp_degree),
    }


# --------------------------------------------------------------------- #
# section 2+3: recovery throughput + loss parity (subprocess children)
# --------------------------------------------------------------------- #
def _train_child(spec_json: str) -> None:
    import types

    from repro.launch.train import train

    spec = json.loads(spec_json)
    base = dict(arch="starcoder2_3b", smoke=True, mesh="2x4",
                strategy="flashcp", attention_impl="xla", dataset="wlb_llm",
                seq_len=256, batch=8, steps=10, lr=1e-3, q_chunk=64,
                grad_compression="none", checkpoint_dir="", ckpt_every=2,
                log_every=1, resume=False, prefetch=False, no_remat=False,
                dispatch=True, dispatch_target=1.1, dispatch_min_cp=1,
                fail_at="", straggle=None, hosts=4, max_restarts=10)
    base.update(spec)
    out = train(types.SimpleNamespace(**base))
    print("RESULT " + json.dumps(
        {k: out[k] for k in ("final_step", "losses", "recoveries",
                             "dead_hosts", "mesh", "accum")}))


def _run_child(spec: dict) -> tuple[dict, list[str]]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--train-child",
         json.dumps(spec)],
        capture_output=True, text=True, env=env, check=True)
    lines = proc.stdout.splitlines()
    result = next(json.loads(ln[len("RESULT "):]) for ln in reversed(lines)
                  if ln.startswith("RESULT "))
    return result, lines


def _rates(lines: list[str]) -> dict:
    """Pre-failure / post-recovery steps/s from the driver's step logs.
    Compile steps dominate a cold mesh, so each phase drops its largest
    sample before the median."""
    pre, post, seen_restore = [], [], False
    for ln in lines:
        if RESTORE_RE.search(ln):
            seen_restore = True
            continue
        m = STEP_RE.search(ln)
        if m:
            (post if seen_restore else pre).append(float(m.group(2)))

    def rate(ts):
        if not ts:
            return None
        ts = sorted(ts)[:-1] if len(ts) > 2 else ts
        return 1.0 / float(np.median(ts))

    return {"pre_rate": rate(pre), "post_rate": rate(post)}


def _recovery_sections(steps: int, fail_step: int, seq_len: int) -> dict:
    with tempfile.TemporaryDirectory() as td:
        oracle, _ = _run_child(
            {"checkpoint_dir": os.path.join(td, "oracle"),
             "steps": steps, "seq_len": seq_len})
        elastic, el_lines = _run_child(
            {"checkpoint_dir": os.path.join(td, "elastic"),
             "steps": steps, "seq_len": seq_len,
             "fail_at": f"{fail_step}:3"})
        restart, rs_lines = _run_child(
            {"checkpoint_dir": os.path.join(td, "restart"),
             "steps": steps, "seq_len": seq_len,
             "fail_at": str(fail_step)})

    el = _rates(el_lines)
    rs = _rates(rs_lines)
    total_dev, surv_dev = 8, 8 - 2 * len(elastic["dead_hosts"])
    capacity = surv_dev / total_dev
    recovery = {
        "fail_step": fail_step,
        "steps": steps,
        "elastic_pre_rate_steps_per_s": el["pre_rate"],
        "elastic_post_rate_steps_per_s": el["post_rate"],
        "restart_post_rate_steps_per_s": rs["post_rate"],
        "recovered_over_restart_measured":
            (el["post_rate"] / rs["post_rate"]
             if el["post_rate"] and rs["post_rate"] else None),
        "capacity_fraction": capacity,
        "recovered_over_restart_modeled": capacity,
        "elastic_completed": elastic["final_step"] == steps,
        "restart_completed": restart["final_step"] == steps,
        "elastic_mesh": elastic["mesh"],
        "elastic_accum": elastic["accum"],
        "elastic_dead_hosts": elastic["dead_hosts"],
    }

    tail = min(3, steps - fail_step)
    o_t = np.asarray(oracle["losses"][-tail:])
    e_t = np.asarray(elastic["losses"][-tail:])
    parity = {
        "oracle_final_loss": float(oracle["losses"][-1]),
        "elastic_final_loss": float(elastic["losses"][-1]),
        "final_rel_diff": float(abs(e_t[-1] - o_t[-1]) /
                                max(abs(o_t[-1]), 1e-9)),
        "tail_max_rel_diff": float(np.max(np.abs(e_t - o_t) /
                                          np.maximum(np.abs(o_t), 1e-9))),
        "tail_steps": tail,
    }
    return {"recovery": recovery, "parity": parity}


# --------------------------------------------------------------------- #
def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    steps, fail_step, seq_len = (8, 5, 256) if smoke else (12, 7, 512)

    results: dict = {"config": {"steps": steps, "fail_step": fail_step,
                                "seq_len": seq_len, "mesh": "2x4",
                                "hosts": 4, "smoke": smoke}}
    results["weighted_lpt"] = _weighted_lpt_section(rng)
    results.update(_recovery_sections(steps, fail_step, seq_len))

    with open(RESULT_JSON, "w") as f:
        json.dump(results, f, indent=1)

    w = results["weighted_lpt"]
    r = results["recovery"]
    p = results["parity"]
    rows = [
        f"elastic_lpt_eff_imb_unweighted,,"
        f"{w['unweighted_effective_imbalance']:.3f}",
        f"elastic_lpt_eff_imb_weighted,,"
        f"{w['weighted_effective_imbalance']:.3f}",
        f"elastic_dispatch_work_imb_unweighted,,"
        f"{w['dispatch_unweighted_work_imbalance']:.3f}",
        f"elastic_dispatch_work_imb_weighted,,"
        f"{w['dispatch_weighted_work_imbalance']:.3f}",
        f"elastic_monitor_slow_speed,,{w['monitor_speeds'][3]:.3f}",
        f"elastic_recovered_completed,,{r['elastic_completed']}",
        f"elastic_capacity_fraction,,{r['capacity_fraction']:.3f}",
        f"elastic_recovered_over_restart_modeled,,"
        f"{r['recovered_over_restart_modeled']:.3f}",
        f"elastic_shrunk_mesh,,{r['elastic_mesh'][0]}x"
        f"{r['elastic_mesh'][1]} accum {r['elastic_accum']}",
        f"elastic_parity_final_rel_diff,,{p['final_rel_diff']:.2e}",
        f"elastic_parity_tail_max_rel_diff,,{p['tail_max_rel_diff']:.2e}",
        f"elastic_json,,{os.path.basename(RESULT_JSON)}",
    ]
    if r["recovered_over_restart_measured"] is not None:
        rows.insert(-3, f"elastic_recovered_over_restart_measured,,"
                        f"{r['recovered_over_restart_measured']:.3f}")
    return rows


if __name__ == "__main__":
    if "--train-child" in sys.argv:
        _train_child(sys.argv[sys.argv.index("--train-child") + 1])
    else:
        for row in run(smoke="--smoke" in sys.argv):
            print(row)
