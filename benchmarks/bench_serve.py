"""Serving suite (``serve``, ``BENCH_serve.json``): decode + prefill.

Decode: the flash-decode kernel clamps its block fetches at each
request's length, so a ragged batch reads only ``sum_b ceil((len_b+1)/
block_k)`` cache blocks per KV head where the dense XLA oracle always
reads ``B * S/block_k``.  The kernel is HBM-bound on the cache read
(§Roofline), so the block-read reduction is the TPU wall-clock proxy —
reported per length mix alongside the *measured* dense XLA wall (which
pays the full cache regardless of raggedness) and the kernel's interpret
wall (reference only: every grid step pays a fixed interpreter cost, so
interpret walls track grid size, not HBM traffic).

Prefill: the engine's chunked cache-writing prefill costs
``ceil(Tp/C)`` forward chunks; the seed driver replayed all ``Tp``
prompt tokens through ``decode_step``.  Step counts and measured engine
prefill walls are reported per prompt length — chunk steps grow as
``ceil(Tp/C)``, never as ``Tp`` decode steps.

Paged: the shared-prefix high-churn mix drives the same workload
through the paged block pool (with and without prefix sharing), the
dense stripe layout, and the serial scheduler — reporting HBM bytes
per live token (paged vs dense), prefix hit rate, prefill-compute
reduction from shared system prompts, and decode-stall steps (unified
token budget vs serial), with bitwise greedy parity asserted across
all four engines.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVE_JSON = os.path.join(ROOT, "BENCH_serve.json")


def _mixes(S, B):
    """Per-request cache lengths for each decode mix."""
    rng = np.random.default_rng(0)
    short = rng.integers(S // 16, S // 8, (B,))
    ragged = short.copy()
    ragged[0] = S - 1                       # one long-cache request
    return {
        "short_uniform": short,
        "long_ragged": ragged,
        "full_uniform": np.full((B,), S - 1),
    }


def _decode_rows(S, B, Hq, Hkv, D, block_k, iters):
    from repro.kernels.flash_decode import decode_reference, flash_decode

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))

    dense = jax.jit(decode_reference)
    flash = jax.jit(lambda *a: flash_decode(*a, block_k=block_k,
                                            interpret=True))

    rows, out = [], {}
    dense_blocks = B * (S // block_k)
    for name, lens in _mixes(S, B).items():
        ln = jnp.asarray(lens, jnp.int32)
        dense(q, k, v, ln).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            dense(q, k, v, ln).block_until_ready()
        dense_us = (time.perf_counter() - t0) / iters * 1e6

        flash(q, k, v, ln).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            flash(q, k, v, ln).block_until_ready()
        flash_us = (time.perf_counter() - t0) / iters * 1e6

        flash_blocks = int(np.sum(-(-(lens + 1) // block_k)))
        red = dense_blocks / flash_blocks
        out[name] = {
            "lengths": lens.tolist(),
            "dense_cache_blocks": dense_blocks,
            "flash_cache_blocks": flash_blocks,
            "hbm_read_reduction_x": red,
            "dense_xla_wall_us": dense_us,
            "flash_interpret_wall_us": flash_us,
        }
        rows.append(f"serve_decode_{name}_dense_blocks,,{dense_blocks}")
        rows.append(f"serve_decode_{name}_flash_blocks,,{flash_blocks}")
        rows.append(f"serve_decode_{name}_hbm_reduction,,{red:.2f}x")
        rows.append(f"serve_decode_{name}_dense_wall,{dense_us:.0f},")
    return rows, out


def _prefill_rows(prompt_lens, chunk, smoke):
    from repro.configs import get_config, reduce_for_smoke
    from repro.serve import ServeEngine

    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    rng = np.random.default_rng(2)
    rows, out = [], {}
    for Tp in prompt_lens:
        eng = ServeEngine(cfg, num_slots=1, max_len=Tp + 8,
                          prefill_chunk=chunk, seed=0)
        eng.warmup(prompt_len=Tp)
        eng.submit(rng.integers(0, cfg.vocab_size, Tp).astype(np.int32),
                   max_new=2)
        eng.run()
        s = eng.stats
        steps = s["prefill_steps"]
        assert s["prefill_decode_steps"] == 0
        red = Tp / steps
        out[f"Tp{Tp}"] = {
            "prompt_len": Tp, "chunk": chunk,
            "prefill_chunk_steps": steps,
            "seed_replay_decode_steps": Tp,
            "step_reduction_x": red,
            "prefill_wall_s": s["prefill_s"],
        }
        rows.append(f"serve_prefill_Tp{Tp}_chunk_steps,,{steps}")
        rows.append(f"serve_prefill_Tp{Tp}_replay_steps_seed,,{Tp}")
        rows.append(f"serve_prefill_Tp{Tp}_step_reduction,,{red:.1f}x")
        rows.append(f"serve_prefill_Tp{Tp}_wall,"
                    f"{s['prefill_s'] * 1e6:.0f},")
    return rows, out


def _bytes_per_live_token(eng):
    """HBM bytes of KV actually *used* per live token, time-averaged
    over engine steps.  Dense stripes reserve num_slots * max_len
    positions no matter what is live; the paged pool holds only the
    allocated blocks."""
    bpt = eng.kv_cache_bytes() / eng.kv_token_capacity()
    steps = max(eng.stats["steps"], 1)
    live = eng.stats["live_token_steps"] / steps
    if eng.layout == "paged":
        used = eng.stats["pool_block_steps"] / steps * eng.block_size
    else:
        used = eng.kv_token_capacity()
    return used * bpt / max(live, 1e-9)


def _paged_rows(smoke):
    """Shared-prefix high-churn mix: many short requests carrying the
    same system prompt churn through few slots while one long prompt
    prefills mid-stream.  Runs the same workload through four engines —
    paged+prefix (primary), paged without prefix sharing, dense stripes
    (parity oracle + HBM baseline), and serial scheduling (stall
    baseline) — asserting bitwise greedy parity across layouts."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.serve import ServeEngine

    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    rng = np.random.default_rng(3)
    prefix_len = 32 if smoke else 64
    long_len = 96 if smoke else 256
    n_short = 6 if smoke else 12
    gen = 6 if smoke else 12
    slots = 3
    sys_p = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    prompts = [np.concatenate([
        sys_p, rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, 24))).astype(np.int32)])
        for _ in range(n_short)]
    # the long prompt arrives mid-churn: decodes are in flight while it
    # prefills — the serial baseline stalls them, the unified step not
    prompts.insert(n_short // 2, np.concatenate([
        sys_p, rng.integers(0, cfg.vocab_size,
                            long_len - prefix_len).astype(np.int32)]))
    max_len = long_len + gen + 8

    def drive(**kw):
        eng = ServeEngine(cfg, num_slots=slots, max_len=max_len,
                          prefill_chunk=16, seed=0, **kw)
        eng.warmup(prompt_len=long_len)
        for p in prompts:
            eng.submit(p, max_new=gen)
        return eng, eng.run()

    eng_p, out_p = drive()                               # paged + prefix
    eng_n, out_n = drive(prefix_cache=False)             # paged, no prefix
    eng_d, out_d = drive(kv_layout="dense")              # dense oracle
    eng_s, out_s = drive(unified=False)                  # serial baseline

    parity = all(
        np.array_equal(out_p[r]["tokens"], out_d[r]["tokens"])
        and np.array_equal(out_p[r]["tokens"], out_n[r]["tokens"])
        and np.array_equal(out_p[r]["tokens"], out_s[r]["tokens"])
        for r in out_p)
    assert parity, "paged/dense/serial greedy token mismatch"

    bpt_p = _bytes_per_live_token(eng_p)
    bpt_d = _bytes_per_live_token(eng_d)
    hbm_red = bpt_d / bpt_p
    assert hbm_red > 1.0, f"paged HBM/token not below dense ({hbm_red})"
    hit_rate = eng_p.prefix.hit_rate()
    assert hit_rate > 0, "shared system prompt produced no prefix hits"
    pf_red = (eng_n.stats["prefill_chunk_tokens"]
              / max(eng_p.stats["prefill_chunk_tokens"], 1))
    assert pf_red > 1.0, "prefix sharing did not reduce prefill compute"
    assert eng_p.stats["stalled_decode_steps"] == 0, \
        "unified token-budget step stalled a decode"
    assert eng_s.stats["stalled_decode_steps"] > 0, \
        "serial baseline shows no stalls — mix too easy to matter"

    out = {
        "mix": {"requests": len(prompts), "slots": slots,
                "shared_prefix": prefix_len, "long_prompt": long_len,
                "gen": gen, "max_len": max_len},
        "paged": {
            "hbm_bytes_per_live_token": bpt_p,
            "prefill_chunk_tokens": eng_p.stats["prefill_chunk_tokens"],
            "prefill_cached_tokens": eng_p.stats["prefill_cached_tokens"],
            "stalled_decode_steps": eng_p.stats["stalled_decode_steps"],
            "cow_copies": eng_p.stats["cow_copies"],
            "admission_backoffs": eng_p.stats["admission_backoffs"],
            "pool": eng_p.pool.stats(),
            "prefix": eng_p.prefix.stats()},
        "paged_no_prefix": {
            "prefill_chunk_tokens": eng_n.stats["prefill_chunk_tokens"]},
        "dense": {"hbm_bytes_per_live_token": bpt_d},
        "serial": {
            "stalled_decode_steps": eng_s.stats["stalled_decode_steps"]},
        "hbm_bytes_per_token_reduction_x": hbm_red,
        "prefill_compute_reduction_x": pf_red,
        "prefix_hit_rate": hit_rate,
        "greedy_parity_paged_dense_serial": parity,
    }
    rows = [
        f"serve_paged_hbm_bytes_per_tok,,{bpt_p:.0f}",
        f"serve_dense_hbm_bytes_per_tok,,{bpt_d:.0f}",
        f"serve_paged_hbm_reduction,,{hbm_red:.2f}x",
        f"serve_paged_prefix_hit_rate,,{hit_rate:.2f}",
        f"serve_paged_prefill_compute_reduction,,{pf_red:.2f}x",
        f"serve_paged_stalled_steps_unified,,"
        f"{eng_p.stats['stalled_decode_steps']}",
        f"serve_paged_stalled_steps_serial,,"
        f"{eng_s.stats['stalled_decode_steps']}",
        f"serve_paged_greedy_parity,,{int(parity)}",
    ]
    return rows, out


def run(smoke: bool = False):
    """``serve`` suite: emits CSV rows and writes BENCH_serve.json."""
    S = 512 if smoke else 4096
    B = 8
    Hq, Hkv, D = 8, 2, 64
    block_k = 64 if smoke else 256
    iters = 2 if smoke else 5
    prompt_lens = (48, 96) if smoke else (128, 512)
    chunk = 16 if smoke else 64

    results = {"config": {
        "S": S, "B": B, "Hq": Hq, "Hkv": Hkv, "D": D, "block_k": block_k,
        "prefill_chunk": chunk, "smoke": smoke,
        "platform": jax.default_backend(),
        "note": ("hbm_read_reduction_x counts cache blocks fetched "
                 "(flash clamps at each request's length; dense reads "
                 "all of S) — the wall-clock proxy for the HBM-bound "
                 "decode kernel.  flash walls here are Pallas interpret "
                 "mode (reference only).")}}

    rows, results["decode"] = _decode_rows(S, B, Hq, Hkv, D, block_k, iters)
    prows, results["prefill"] = _prefill_rows(prompt_lens, chunk, smoke)
    rows += prows
    grows, results["paged"] = _paged_rows(smoke)
    rows += grows

    headline = results["decode"]["long_ragged"]["hbm_read_reduction_x"]
    results["decode_speedup_long_ragged_x"] = headline
    rows.append(f"serve_decode_speedup_long_ragged,,{headline:.2f}x")

    with open(SERVE_JSON, "w") as f:
        json.dump(results, f, indent=1)
    rows.append(f"serve_json,,{os.path.basename(SERVE_JSON)}")
    return rows
