"""Compatibility shim: the analytic step-cost model moved to
:mod:`repro.autotune.cost_model` so the autotuner can import it without
reaching into the benchmark tree.  Benchmarks keep importing from here.
"""

from repro.autotune.cost_model import (BLOCK, HW, L_HALF, ModelDims,
                                       _attention_block_work, _kernel_eff,
                                       step_breakdown, visited_tile_counts)

__all__ = ["BLOCK", "HW", "L_HALF", "ModelDims", "_attention_block_work",
           "_kernel_eff", "step_breakdown", "visited_tile_counts"]
