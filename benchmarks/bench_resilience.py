"""Resilience suite (``resilience``, ``BENCH_resilience.json``).

Three scenarios against the serve engine (DESIGN.md
§Serving-resilience):

Overload: an arrival-driven 2x-overload trace (identical for every
policy) through a bounded queue, comparing strict FIFO shedding
(lookahead 0 — the parity baseline) against deadline-aware shedding +
bounded look-ahead admission.  Reports goodput (tokens of requests
that finished *within deadline* per engine step), shed rate, and
p50/p99 request latency — deadline admission must beat FIFO on
goodput: FIFO spends service on stale requests that miss their
deadlines anyway, while the deadline policy sheds the least-slack
victims and drops queued requests whose deadline is already
unmeetable.

Chaos: the same workload uninjected vs with a NaN-logits fault and a
stuck slot.  The watchdog must abort exactly the poisoned requests
while every healthy request's tokens stay bitwise identical to the
uninjected run (per-request keyed sampling).

Restore: snapshot every N steps, kill the engine mid-decode, restore
into a fresh engine and finish — zero request loss and bitwise token
parity against the uninterrupted run (temperature sampling included,
proving per-request RNG counters survive the snapshot).
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESILIENCE_JSON = os.path.join(ROOT, "BENCH_resilience.json")


def _engine(cfg, *, chaos=None, **kw):
    from repro.serve import ServeEngine
    base = dict(num_slots=2, max_len=64, prefill_chunk=8, seed=0)
    return ServeEngine(cfg, chaos=chaos, **{**base, **kw})


def _drive_arrivals(eng, arrivals, gen, deadline):
    """Feed ``arrivals`` = [(due_step, prompt)] into a live engine loop:
    each request is submitted the step it arrives, not up front."""
    i = 0
    while i < len(arrivals) or eng.sched.has_work:
        while i < len(arrivals) and arrivals[i][0] <= eng.stats["steps"]:
            eng.submit(arrivals[i][1], max_new=gen,
                       deadline_steps=deadline)
            i += 1
        eng.step()
        assert eng.stats["steps"] < 10_000, "overload trace wedged"
    return eng.sched.finished


def _overload_rows(smoke):
    from repro.configs import get_config, reduce_for_smoke

    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    rng = np.random.default_rng(0)
    n = 16 if smoke else 40
    # deadline 12 ~= optimistic service estimate (7 steps) + a short
    # queue wait: a request stuck behind a full queue provably misses
    # it, so FIFO wastes service on doomed work the deadline policy
    # sheds up front
    Tp, gen, deadline, max_queue = 16, 6, 12, 4
    # capacity ~= token_budget (10) tokens/step, demand Tp+gen per
    # request: ~2.2 steps/request at saturation -> 2x overload arrives
    # one request every 1.1 steps
    arrivals = [(int(i * 1.1),
                 rng.integers(0, cfg.vocab_size, Tp).astype(np.int32))
                for i in range(n)]

    def policy_run(admission, lookahead):
        eng = _engine(cfg, max_queue=max_queue, admission=admission,
                      admit_lookahead=lookahead)
        eng.warmup(prompt_len=Tp)
        res = _drive_arrivals(eng, arrivals, gen, deadline)
        assert set(res) == set(range(n)), "request lost under overload"
        good = sum(len(r["tokens"]) for r in res.values()
                   if r["deadline_met"])
        lat = eng.latency_percentiles()
        return {
            "goodput_tokens_per_step": good / max(eng.stats["steps"], 1),
            "good_tokens": good,
            "deadline_met": sum(r["deadline_met"] for r in res.values()),
            "completed_ok": sum(r["status"] == "ok"
                                for r in res.values()),
            "shed": sum(r["status"] == "shed" for r in res.values()),
            "shed_rate": sum(r["status"] == "shed"
                             for r in res.values()) / n,
            "shed_by_reason": dict(eng.stats["shed_by_reason"]),
            "steps": eng.stats["steps"],
            "p50_steps": lat["p50_steps"], "p99_steps": lat["p99_steps"],
            "p50_s": lat["p50_s"], "p99_s": lat["p99_s"],
        }

    fifo = policy_run("fifo", lookahead=0)
    dl = policy_run("deadline", lookahead=4)
    ratio = dl["goodput_tokens_per_step"] \
        / max(fifo["goodput_tokens_per_step"], 1e-9)
    assert ratio > 1.0, (
        f"deadline admission did not beat FIFO on goodput ({ratio:.3f}x: "
        f"deadline {dl['goodput_tokens_per_step']:.3f} vs FIFO "
        f"{fifo['goodput_tokens_per_step']:.3f} tok/step)")

    out = {"trace": {"requests": n, "prompt_len": Tp, "gen": gen,
                     "deadline_steps": deadline, "max_queue": max_queue,
                     "arrival_period_steps": 1.1},
           "fifo": fifo, "deadline": dl,
           "goodput_gain_x": ratio}
    rows = []
    for name, p in (("fifo", fifo), ("deadline", dl)):
        rows += [
            f"resil_overload_{name}_goodput_tok_per_step,,"
            f"{p['goodput_tokens_per_step']:.3f}",
            f"resil_overload_{name}_deadline_met,,{p['deadline_met']}",
            f"resil_overload_{name}_shed_rate,,{p['shed_rate']:.2f}",
            f"resil_overload_{name}_p50_steps,,{p['p50_steps']:.0f}",
            f"resil_overload_{name}_p99_steps,,{p['p99_steps']:.0f}",
        ]
    rows.append(f"resil_overload_goodput_gain,,{ratio:.2f}x")
    return rows, out


def _chaos_rows(smoke):
    from repro.configs import get_config, reduce_for_smoke
    from repro.serve import ChaosInjector

    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    rng = np.random.default_rng(1)
    n = 4 if smoke else 8
    gen = 6
    prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(n)]

    def drive(chaos=None):
        eng = _engine(cfg, chaos=chaos, stall_patience=4)
        eng.warmup(prompt_len=24)
        for p in prompts:
            eng.submit(p, max_new=gen)
        return eng, eng.run(max_steps=500)

    _, base = drive()
    assert all(r["status"] == "ok" for r in base.values())
    poisoned = {1, 2}
    eng, res = drive(ChaosInjector(nan_logits={1: 6}, stuck={2: 8}))
    for r in res:
        if r in poisoned:
            assert res[r]["status"] == "aborted", res[r]
        else:
            assert res[r]["status"] == "ok"
            assert np.array_equal(res[r]["tokens"], base[r]["tokens"]), \
                f"healthy request {r} diverged under chaos"
    healthy_tok = sum(len(res[r]["tokens"]) for r in res
                      if r not in poisoned)
    out = {
        "requests": n, "poisoned": sorted(poisoned),
        "aborted_by_reason": dict(eng.stats["aborted_by_reason"]),
        "healthy_bitwise_identical": True,
        "healthy_tokens": healthy_tok,
        "steps": eng.stats["steps"],
    }
    rows = [
        f"resil_chaos_aborted,,{sum(out['aborted_by_reason'].values())}",
        f"resil_chaos_healthy_ok,,{n - len(poisoned)}",
        "resil_chaos_healthy_bitwise,,1",
    ]
    return rows, out


def _restore_rows(smoke, tmp_dir):
    from repro.configs import get_config, reduce_for_smoke
    from repro.serve import ChaosInjector, EngineKilled

    cfg = reduce_for_smoke(get_config("starcoder2_3b"))
    rng = np.random.default_rng(2)
    n = 4 if smoke else 6
    gen = 6
    prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(n)]

    def submit_all(eng):
        for p in prompts:
            eng.submit(p, max_new=gen, temperature=1.0, top_k=8)

    ref = _engine(cfg)
    ref.warmup(prompt_len=24)
    submit_all(ref)
    expected = ref.run()

    snap = os.path.join(tmp_dir, "resil_snap")
    killed = _engine(cfg, chaos=ChaosInjector(kill_at=7))
    killed.warmup(prompt_len=24)
    submit_all(killed)
    try:
        killed.run(snapshot_every=3, snapshot_dir=snap)
        raise AssertionError("kill injection never fired")
    except EngineKilled:
        pass

    eng = _engine(cfg)
    eng.warmup(prompt_len=24)
    step = eng.restore_snapshot(snap)
    res = eng.run()
    assert set(res) == set(expected), "request lost across kill/restore"
    parity = all(np.array_equal(res[r]["tokens"], expected[r]["tokens"])
                 and res[r]["status"] == "ok" for r in expected)
    assert parity, "restored engine diverged from uninterrupted run"

    out = {"requests": n, "kill_at_step": 7, "snapshot_every": 3,
           "restored_step": step,
           "snapshots_taken": killed.stats["snapshots"],
           "bitwise_parity": parity, "temperature_sampling": True}
    rows = [
        f"resil_restore_step,,{step}",
        f"resil_restore_parity,,{int(parity)}",
        f"resil_restore_requests,,{n}",
    ]
    return rows, out


def run(smoke: bool = False):
    """``resilience`` suite: emits CSV rows, writes
    BENCH_resilience.json."""
    import tempfile

    results = {"config": {
        "smoke": smoke, "platform": jax.default_backend(),
        "note": ("goodput counts tokens of requests finishing within "
                 "their deadline per engine step; the 2x-overload trace "
                 "is identical across policies.  Chaos/restore parity "
                 "is bitwise (per-request keyed sampling).")}}

    rows, results["overload"] = _overload_rows(smoke)
    crows, results["chaos"] = _chaos_rows(smoke)
    rows += crows
    with tempfile.TemporaryDirectory() as td:
        rrows, results["restore"] = _restore_rows(smoke, td)
    rows += rrows

    headline = results["overload"]["goodput_gain_x"]
    results["goodput_gain_x"] = headline
    rows.append(f"resil_goodput_gain,,{headline:.2f}x")

    with open(RESILIENCE_JSON, "w") as f:
        json.dump(results, f, indent=1)
    rows.append(f"resil_json,,{os.path.basename(RESILIENCE_JSON)}")
    return rows
