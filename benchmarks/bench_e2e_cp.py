"""Paper Fig. 5 — end-to-end CP training/inference step comparison.

Datasets {WLB-LLM, Pile, RedPajama} x heads {16, 32} x CP {4, 8}, context
window 128K, head dim 128 (the paper's grid).  Per method, per sampled
packed sequence: build the plan, evaluate the v5e cost model, report mean
step time and the speedup of FlashCP normalized to Llama3 CP (the paper's
normalization).
"""

from __future__ import annotations

import numpy as np

from repro.planner.baselines import BASELINE_PLANNERS
from repro.data.distributions import make_rng
from repro.data.packing import pack_sequence

from .cost_model import ModelDims, step_breakdown

METHODS = ["llama3", "per_doc", "ring_zigzag", "flashcp"]
DATASETS = ["wlb_llm", "pile", "redpajama"]


def evaluate(dataset: str, cp: int, heads: int, *, context=131072,
             n_seqs=12, train=True, seed=0) -> dict[str, float]:
    rng = make_rng(seed)
    dims = ModelDims(num_heads=heads, kv_heads=8, head_dim=128)
    totals = {m: [] for m in METHODS}
    for _ in range(n_seqs):
        lens = pack_sequence(dataset, context, rng)
        for m in METHODS:
            plan = BASELINE_PLANNERS[m](lens, cp)
            totals[m].append(
                step_breakdown(plan, dims, train=train)["total_s"])
    return {m: float(np.mean(v)) for m, v in totals.items()}


def run() -> list[str]:
    rows = []
    speedups_pd, speedups_l3, speedups_ring = [], [], []
    for dataset in DATASETS:
        for heads in (16, 32):
            for cp in (4, 8):
                for train in (True, False):
                    t = evaluate(dataset, cp, heads, train=train)
                    mode = "train" if train else "infer"
                    rows.append(
                        f"fig5_{dataset}_H{heads}_CP{cp}_{mode},"
                        f"{t['flashcp']*1e6:.0f},"
                        + ";".join(
                            f"speedup_vs_{m}={t[m]/t['flashcp']:.2f}"
                            for m in METHODS if m != "flashcp"))
                    speedups_l3.append(t["llama3"] / t["flashcp"])
                    speedups_pd.append(t["per_doc"] / t["flashcp"])
                    speedups_ring.append(t["ring_zigzag"] / t["flashcp"])
    rows.append(f"fig5_mean_speedup_vs_llama3,,"
                f"{np.mean(speedups_l3):.2f}x_paper_1.38x")
    rows.append(f"fig5_mean_speedup_vs_perdoc,,"
                f"{np.mean(speedups_pd):.2f}x_paper_up_to_1.63x")
    rows.append(f"fig5_mean_speedup_vs_ring_zigzag,,"
                f"{np.mean(speedups_ring):.2f}x_paper_2.14x")
    return rows
