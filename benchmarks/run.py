"""Benchmark harness: one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call empty where the
row is a ratio/summary).  Suites:

  fig3   kernel efficiency vs sharding granularity
  fig5   e2e CP comparison (3 datasets x heads x CP size, train+infer)
  fig6   latency breakdown (comm/attn/other) + comm-reduction headline
  fig7   context-window sweep
  table2 exact (B&B) vs heuristic optimality
  extra  planner runtime
  overlap blocking vs chunked CP execution + visit-table builder

Usage: PYTHONPATH=src python -m benchmarks.run [suite ...]
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_breakdown, bench_context_window, bench_e2e_cp,
                   bench_ilp_vs_heuristic, bench_kernel_efficiency,
                   bench_overlap, bench_planner_runtime)

    suites = {
        "fig3": bench_kernel_efficiency.run,
        "fig5": bench_e2e_cp.run,
        "fig6": bench_breakdown.run,
        "fig7": bench_context_window.run,
        "table2": bench_ilp_vs_heuristic.run,
        "planner": bench_planner_runtime.run,
        "overlap": bench_overlap.run,
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in want:
        t0 = time.time()
        for row in suites[name]():
            print(row, flush=True)
        print(f"suite_{name}_wallclock,{(time.time()-t0)*1e6:.0f},",
              flush=True)


if __name__ == "__main__":
    main()
