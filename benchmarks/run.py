"""Benchmark harness: one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call empty where the
row is a ratio/summary).  Suites:

  fig3   kernel efficiency vs sharding granularity
  fig5   e2e CP comparison (3 datasets x heads x CP size, train+infer)
  fig6   latency breakdown (comm/attn/other) + comm-reduction headline
  fig7   context-window sweep
  table2 exact (B&B) vs heuristic optimality
  planner  planner runtime
  overlap blocking vs chunked CP execution + visit-table builder
  kernel  rect vs flat work-queue kernel grids (BENCH_kernel.json)
  serve   flash-decode vs dense serving + chunked prefill (BENCH_serve.json)
  dispatch  adaptive DP×CP token dispatch vs static (BENCH_dispatch.json)
  elastic  degree-replanning recovery + straggler-weighted balancing
           (BENCH_elastic.json)
  resilience  overload shedding goodput + chaos quarantine +
           kill/restore parity (BENCH_resilience.json)
  autotune  config-tuner rank quality: full-space predicted-vs-measured
           Spearman + tuner-vs-brute-force optimum (BENCH_autotune.json)

Usage: PYTHONPATH=src python -m benchmarks.run [suite ...]
       PYTHONPATH=src python -m benchmarks.run --suite kernel [--smoke]

``--smoke`` runs size-reduced variants of the suites that support it
(CI tier-2 uses ``--suite kernel --smoke``).
"""

from __future__ import annotations

import argparse
import inspect
import time


def main() -> None:
    from . import (bench_autotune, bench_breakdown, bench_context_window,
                   bench_dispatch, bench_e2e_cp, bench_elastic,
                   bench_ilp_vs_heuristic, bench_kernel_efficiency,
                   bench_overlap, bench_planner_runtime, bench_resilience,
                   bench_serve)

    suites = {
        "fig3": bench_kernel_efficiency.run,
        "fig5": bench_e2e_cp.run,
        "fig6": bench_breakdown.run,
        "fig7": bench_context_window.run,
        "table2": bench_ilp_vs_heuristic.run,
        "planner": bench_planner_runtime.run,
        "overlap": bench_overlap.run,
        "kernel": bench_kernel_efficiency.run_kernel,
        "serve": bench_serve.run,
        "dispatch": bench_dispatch.run,
        "elastic": bench_elastic.run,
        "resilience": bench_resilience.run,
        "autotune": bench_autotune.run,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*", metavar="suite",
                    help="suites to run (positional form)")
    ap.add_argument("--suite", action="append", default=[],
                    choices=list(suites), help="suite to run (repeatable)")
    ap.add_argument("--smoke", action="store_true",
                    help="size-reduced run for suites that support it")
    args = ap.parse_args()

    want = args.suite + args.names or list(suites)
    unknown = [n for n in want if n not in suites]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from {list(suites)}")
    print("name,us_per_call,derived")
    for name in want:
        fn = suites[name]
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        t0 = time.time()
        for row in fn(**kwargs):
            print(row, flush=True)
        print(f"suite_{name}_wallclock,{(time.time()-t0)*1e6:.0f},",
              flush=True)


if __name__ == "__main__":
    main()
