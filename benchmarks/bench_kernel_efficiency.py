"""Paper Fig. 3 — kernel efficiency vs sharding granularity — plus the
``kernel`` suite measuring the flattened work-queue schedule.

Fig. 3 (``run``): two input patterns at the same total length, one long
document vs many short documents (the paper uses 1x128K vs 16x8K):

  * measured CPU latency of the XLA attention path (relative effect);
  * visit-table occupancy of the Pallas kernel (visited/full fractions —
    the TPU-side efficiency this maps to);
  * modeled v5e attention time (cost model, incl. per-shard overhead).

Scaled to 1x16K vs 16x1K so the CPU measurement is tractable; the
structure (not the absolute size) drives the effect.

Kernel-scheduling suite (``run_kernel``, ``BENCH_kernel.json``): the
rect-vs-flat grid comparison of ISSUE 3 on uniform vs heavy-tail doc
mixes —

  * **grid steps executed** per head at 131072 tokens (host table
    accounting: rect = nq * V_max rows-x-padded-width; flat = the actual
    visit count + empty-row sentinels + pow2 tail);
  * **padding-waste ratio** (fraction of launched steps that do no
    work) for both schedules, and the flat/rect step-reduction factor;
  * **wall time** of both schedules in interpret mode at a reduced size
    (every grid step pays a fixed interpreter cost, so step reduction
    shows up directly; the TPU win tracks the same step counts) plus
    host table-build time at full size;
  * fwd + grad **parity** between the two schedules (allclose at f32
    tolerance — same visit set, different accumulation order).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.planner.baselines import per_doc_plan
from repro.planner.plan import Shard, ShardingPlan
from repro.kernels.doc_attention import build_block_tables
from repro.kernels.ops import doc_attention_xla

from .cost_model import ModelDims, step_breakdown

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KERNEL_JSON = os.path.join(ROOT, "BENCH_kernel.json")


def _measure(doc_lens, T, H, D, iters=3):
    doc = np.repeat(np.arange(len(doc_lens), dtype=np.int32), doc_lens)[None]
    pos = np.concatenate([np.arange(d, dtype=np.int32)
                          for d in doc_lens])[None]
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    jd, jp = jnp.asarray(doc), jnp.asarray(pos)

    f = jax.jit(lambda *a: doc_attention_xla(*a, q_chunk=512))
    f(q, k, v, jd, jp, jd, jp).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(q, k, v, jd, jp, jd, jp).block_until_ready()
    lat = (time.perf_counter() - t0) / iters

    tabs = build_block_tables(doc, pos, doc, pos)
    return lat, tabs


def run() -> list[str]:
    T, H, D = 16384, 4, 64
    rows = []
    for name, lens in (("whole_1x16k", [T]),
                       ("short_16x1k", [1024] * 16),
                       ("short_64x256", [256] * 64)):
        lat, tabs = _measure(np.asarray(lens), T, H, D)
        # modeled v5e time for the same structure, whole vs per-doc shards
        dims = ModelDims(num_heads=H, kv_heads=H, head_dim=D)
        plan = ShardingPlan(doc_lens=np.asarray(lens), shards=[
            Shard(i, 0, int(l), 0) for i, l in enumerate(lens)],
            num_workers=1)
        model = step_breakdown(plan, dims, train=False)
        rows.append(
            f"fig3_kernel_eff_{name},{lat*1e6:.0f},"
            f"visited={tabs.visited_frac:.3f};full={tabs.full_frac:.3f};"
            f"v5e_attn_us={model['attn_s']*1e6:.1f}")

    # per-doc sharding of the same 16x1K mix across 8 CP workers
    plan = per_doc_plan([1024] * 16, 8)
    dims = ModelDims(num_heads=H, kv_heads=H, head_dim=D)
    model = step_breakdown(plan, dims, train=False)
    rows.append(f"fig3_perdoc_cp8_16x1k,,shards={len(plan.shards)};"
                f"v5e_attn_us={model['attn_s']*1e6:.1f}")
    return rows


# ===================================================================== #
# kernel-scheduling suite: rect vs flat work-queue grids
# ===================================================================== #
def _mix_layout(doc_lens):
    lens = np.asarray(doc_lens, np.int64)
    doc = np.repeat(np.arange(len(lens), dtype=np.int32), lens)[None]
    pos = np.concatenate([np.arange(l, dtype=np.int32)
                          for l in lens])[None]
    return doc, pos


def _mixes(T):
    """Uniform vs heavy-tail doc mixes at total length T (the skew FlashCP
    plans around: one document owns half the context, a tail of short
    docs the rest)."""
    n_uni = 16
    heavy = [T // 2] + [T // 64] * 32
    assert sum(heavy) == T
    return {
        "uniform": [T // n_uni] * n_uni,
        "heavy_tail": heavy,
    }


def _step_stats(doc, pos, block):
    t0 = time.perf_counter()
    tabs = build_block_tables(doc, pos, doc, pos, block_q=block,
                              block_k=block)
    build_us = (time.perf_counter() - t0) * 1e6
    t1 = time.perf_counter()
    g = tabs.grid_steps()       # forces the lazy work-queue flatten
    queue_us = (time.perf_counter() - t1) * 1e6
    return tabs, {
        "rect_steps": g["rect_fwd"],
        "flat_steps": g["flat_fwd"],
        "visits": g["visits"],
        "step_reduction_x": g["rect_fwd"] / max(g["flat_fwd"], 1),
        "padding_waste_rect": 1.0 - g["visits"] / max(g["rect_fwd"], 1),
        "padding_waste_flat": 1.0 - g["visits"] / max(g["flat_fwd"], 1),
        "table_build_us": build_us,         # rect tables (all consumers)
        "queue_flatten_us": queue_us,       # extra cost of grid="flat"
    }


def _interpret_wall(doc, pos, tabs, *, iters):
    """Interpret-mode kernel wall per schedule (fixed per-step cost makes
    this a faithful proxy for the step-count effect)."""
    from repro.kernels.ops import doc_flash_attention

    H, D = 2, 64
    T = doc.shape[1]
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    jd, jp = jnp.asarray(doc), jnp.asarray(pos)

    out = {}
    outs = {}
    for grid in ("rect", "flat"):
        f = jax.jit(lambda q, k, v, g=grid: doc_flash_attention(
            q, k, v, jd, jp, jd, jp, tabs.as_jax() if g == "rect"
            else tabs.flat_as_jax(), grid=g, block_q=tabs.block_q,
            block_k=tabs.block_k, interpret=True))
        outs[grid] = f(q, k, v).block_until_ready()
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            f(q, k, v).block_until_ready()
            ts.append(time.perf_counter() - t0)
        out[f"{grid}_us"] = min(ts) * 1e6
    out["speedup_x"] = out["rect_us"] / max(out["flat_us"], 1e-9)
    out["max_abs_diff"] = float(jnp.max(jnp.abs(
        outs["flat"] - outs["rect"])))
    return out


def _parity(block):
    """fwd + grad flat-vs-rect agreement on a small random doc layout."""
    from repro.kernels.ops import doc_flash_attention

    rng = np.random.default_rng(1)
    B, Hq, Hkv, T, D = 1, 4, 2, 512, 16
    doc = np.sort(rng.integers(0, 5, (B, T)).astype(np.int32), 1)
    pos = np.zeros_like(doc)
    for d in np.unique(doc):
        m = doc[0] == d
        pos[0, m] = np.arange(m.sum())
    tabs = build_block_tables(doc, pos, doc, pos, block_q=block,
                              block_k=block)
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)).astype(np.float32))
    jd, jp = jnp.asarray(doc), jnp.asarray(pos)

    res = {}
    for grid in ("rect", "flat"):
        def f(q, k, v, g=grid):
            return jnp.sum(doc_flash_attention(
                q, k, v, jd, jp, jd, jp, tabs, grid=g,
                interpret=True) ** 2)
        loss, grads = jax.value_and_grad(f, (0, 1, 2))(q, k, v)
        res[grid] = (loss, grads)
    fwd_diff = abs(float(res["flat"][0]) - float(res["rect"][0])) \
        / max(abs(float(res["rect"][0])), 1e-9)
    grad_diff = max(float(jnp.max(jnp.abs(a - b)))
                    for a, b in zip(res["flat"][1], res["rect"][1]))
    return {"fwd_rel_diff": fwd_diff, "grad_max_abs_diff": grad_diff,
            "pass": bool(fwd_diff < 1e-5 and grad_diff < 5e-4)}


def run_kernel(smoke: bool = False):
    """``kernel`` suite: emits CSV rows and writes BENCH_kernel.json."""
    block = 128
    T_steps = 16_384 if smoke else 131_072      # step accounting size
    T_wall = 1_024 if smoke else 4_096          # interpret-wall size
    iters = 1 if smoke else 3

    results = {"config": {"block": block, "tokens": T_steps,
                          "wall_tokens": T_wall, "smoke": smoke}}
    rows = []
    mixes = {}
    for name, lens in _mixes(T_steps).items():
        doc, pos = _mix_layout(lens)
        _, stats = _step_stats(doc, pos, block)
        stats["num_docs"] = len(lens)
        mixes[name] = stats
        rows.append(f"kernel_{name}_steps_rect,,{stats['rect_steps']}")
        rows.append(f"kernel_{name}_steps_flat,,{stats['flat_steps']}")
        rows.append(f"kernel_{name}_step_reduction,,"
                    f"{stats['step_reduction_x']:.2f}x")
        rows.append(f"kernel_{name}_padding_waste_rect,,"
                    f"{stats['padding_waste_rect']:.3f}")
        rows.append(f"kernel_{name}_padding_waste_flat,,"
                    f"{stats['padding_waste_flat']:.3f}")
        rows.append(f"kernel_{name}_table_build,"
                    f"{stats['table_build_us']:.0f},")
    results["mixes"] = mixes

    wall = {"tokens": T_wall}
    for name, lens in _mixes(T_wall).items():
        doc, pos = _mix_layout(lens)
        tabs, _ = _step_stats(doc, pos, block)
        wall[name] = _interpret_wall(doc, pos, tabs, iters=iters)
        rows.append(f"kernel_{name}_interpret_rect,"
                    f"{wall[name]['rect_us']:.0f},")
        rows.append(f"kernel_{name}_interpret_flat,"
                    f"{wall[name]['flat_us']:.0f},")
        rows.append(f"kernel_{name}_interpret_speedup,,"
                    f"{wall[name]['speedup_x']:.2f}x")
    results["interpret_wall"] = wall

    results["parity"] = _parity(block)
    rows.append(f"kernel_parity_pass,,{results['parity']['pass']}")

    with open(KERNEL_JSON, "w") as f:
        json.dump(results, f, indent=1)
    rows.append(f"kernel_json,,{KERNEL_JSON}")
    return rows
