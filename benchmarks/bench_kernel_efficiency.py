"""Paper Fig. 3 — kernel efficiency vs sharding granularity.

Two input patterns at the same total length: one long document vs many
short documents (the paper uses 1x128K vs 16x8K).  Three views:

  * measured CPU latency of the XLA attention path (relative effect);
  * visit-table occupancy of the Pallas kernel (visited/full fractions —
    the TPU-side efficiency this maps to);
  * modeled v5e attention time (cost model, incl. per-shard overhead).

Scaled to 1x16K vs 16x1K so the CPU measurement is tractable; the
structure (not the absolute size) drives the effect.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.baselines import per_doc_plan
from repro.core.plan import Shard, ShardingPlan
from repro.kernels.doc_attention import build_block_tables
from repro.kernels.ops import doc_attention_xla

from .cost_model import HW, ModelDims, step_breakdown


def _measure(doc_lens, T, H, D, iters=3):
    doc = np.repeat(np.arange(len(doc_lens), dtype=np.int32), doc_lens)[None]
    pos = np.concatenate([np.arange(d, dtype=np.int32)
                          for d in doc_lens])[None]
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, H, T, D)).astype(np.float32))
    jd, jp = jnp.asarray(doc), jnp.asarray(pos)

    f = jax.jit(lambda *a: doc_attention_xla(*a, q_chunk=512))
    f(q, k, v, jd, jp, jd, jp).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(q, k, v, jd, jp, jd, jp).block_until_ready()
    lat = (time.perf_counter() - t0) / iters

    tabs = build_block_tables(doc, pos, doc, pos)
    return lat, tabs


def run() -> list[str]:
    T, H, D = 16384, 4, 64
    rows = []
    for name, lens in (("whole_1x16k", [T]),
                       ("short_16x1k", [1024] * 16),
                       ("short_64x256", [256] * 64)):
        lat, tabs = _measure(np.asarray(lens), T, H, D)
        # modeled v5e time for the same structure, whole vs per-doc shards
        dims = ModelDims(num_heads=H, kv_heads=H, head_dim=D)
        plan = ShardingPlan(doc_lens=np.asarray(lens), shards=[
            Shard(i, 0, int(l), 0) for i, l in enumerate(lens)],
            num_workers=1)
        model = step_breakdown(plan, dims, train=False)
        rows.append(
            f"fig3_kernel_eff_{name},{lat*1e6:.0f},"
            f"visited={tabs.visited_frac:.3f};full={tabs.full_frac:.3f};"
            f"v5e_attn_us={model['attn_s']*1e6:.1f}")

    # per-doc sharding of the same 16x1K mix across 8 CP workers
    plan = per_doc_plan([1024] * 16, 8)
    dims = ModelDims(num_heads=H, kv_heads=H, head_dim=D)
    model = step_breakdown(plan, dims, train=False)
    rows.append(f"fig3_perdoc_cp8_16x1k,,shards={len(plan.shards)};"
                f"v5e_attn_us={model['attn_s']*1e6:.1f}")
    return rows
